"""Data-parallel (Yahoo!LDA-style) baseline for the paper's comparisons.

Every worker holds the document shard AND a full local copy of the
word-topic table; workers sweep their tokens against the (stale) local
copy and deltas are reconciled by an all-reduce.  ``syncs_per_iter``
controls staleness: 1 = classic AD-LDA (Newman et al. 2007, reconcile once
per iteration); larger values approximate Yahoo!LDA's continuous background
sync; the paper's point (Figs 2–4) is that ANY finite sync rate leaves
parallelization error in ``{C_k^t}``, which the model-parallel engine
eliminates by construction.

Per-worker model memory is ``O(V·K)`` regardless of M — the "big model"
failure mode of Table 1 / Fig 4a.

Since the engine grew the hybrid 2D ``(data, model)`` grid (DESIGN.md §8)
this reconciliation logic also lives INSIDE ``core/engine`` as the
degenerate ``M = 1`` configuration: one model worker × ``D`` replicas
gives every replica the whole table, one round per iteration (at ``S=1``)
and a delta all-reduce at the round boundary — exactly AD-LDA
(:func:`adlda_engine` builds it).  This module is kept as the thin
self-contained baseline the Fig 2–4 comparisons and the staleness
regression tests run against: it chunk-splits tokens (``syncs_per_iter``)
rather than vocabulary blocks, which is the classic Yahoo!LDA staleness
model the paper argues against.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.counts import CountState
from repro.core.likelihood import doc_log_likelihood, word_log_likelihood
from repro.core.sampler import sweep_block_scan
from repro.data.corpus import Corpus
from repro.data.sharding import worker_shard


@partial(jax.jit, static_argnames=("syncs_per_iter",))
def _iteration_dp(cdk, ckt_local, ck_local, ckt_global, ck_global,
                  doc, word, z, mask, u, alpha, beta, vbeta,
                  syncs_per_iter: int = 1):
    """One data-parallel iteration, stacked over workers (vmap backend).

    ``doc/word/z/mask/u`` have shape [M, S, T]: per-worker tokens split
    into ``S = syncs_per_iter`` chunks of capacity T.
    """
    num_workers = doc.shape[0]

    def chunk_step(carry, xs):
        cdk, ckt_loc, ck_loc = carry
        d, t, zz, mk, uu = xs

        def one(cdk, ckt, ck, d, t, zz, mk, uu):
            return sweep_block_scan(cdk, ckt, ck, d, t, zz, mk, uu,
                                    alpha, beta, vbeta, use_eq3=False)

        cdk, ckt_loc, ck_loc, z_new = jax.vmap(one)(
            cdk, ckt_loc, ck_loc, d, t, zz, mk, uu)
        return (cdk, ckt_loc, ck_loc), z_new

    z_chunks, errs = [], []
    ckt_g, ck_g = ckt_global, ck_global
    carry = (cdk, ckt_local, ck_local)
    for s in range(syncs_per_iter):
        xs = (doc[:, s], word[:, s], z[:, s], mask[:, s], u[:, s])
        carry, z_new = chunk_step(carry, xs)
        cdk, ckt_loc, ck_loc = carry
        # all-reduce of deltas (the "background synchronization"):
        # global' = global + sum_m (local_m - global); locals reset to global'.
        ckt_g = ckt_g + (ckt_loc - ckt_g[None]).sum(axis=0)
        ck_g = ck_g + (ck_loc - ck_g[None]).sum(axis=0)
        # staleness error at reconciliation time (Fig-3 analogue for DP):
        # each worker sampled the chunk against ckt_loc, which now differs
        # from the reconciled table by every other worker's updates.
        n_tokens = jnp.maximum(ck_g.sum(), 1).astype(jnp.float32)
        errs.append(jnp.abs(ckt_loc - ckt_g[None]).sum().astype(jnp.float32)
                    / (num_workers * n_tokens))
        ckt_loc = jnp.broadcast_to(ckt_g, ckt_loc.shape)
        ck_loc = jnp.broadcast_to(ck_g, ck_loc.shape)
        carry = (cdk, ckt_loc, ck_loc)
        z_chunks.append(z_new)
    z_out = jnp.stack(z_chunks, axis=1)
    return cdk, ckt_loc, ck_loc, ckt_g, ck_g, z_out, jnp.stack(errs)


def adlda_engine(corpus: Corpus, num_topics: int, num_replicas: int,
                 blocks_per_worker: int = 1, **kwargs):
    """AD-LDA as the degenerate hybrid-engine configuration (DESIGN.md §8).

    ``M = 1`` model worker × ``D = num_replicas`` data replicas: every
    replica holds the full word-topic table (the vocabulary is one block
    per slot, ``B = S``), each iteration runs ``S`` rounds, and the
    engine's per-round delta psum along ``data`` IS the AD-LDA all-reduce
    — ``blocks_per_worker`` plays the role of ``syncs_per_iter``, slicing
    sync points by vocabulary block instead of token chunk.  Returns a
    :class:`repro.core.engine.api.ModelParallelLDA`, so ``delta_error()``
    and the oracle harness apply unchanged.
    """
    from repro.core.engine.api import ModelParallelLDA
    return ModelParallelLDA(corpus, num_topics, num_workers=1,
                            data_parallel=num_replicas,
                            blocks_per_worker=blocks_per_worker, **kwargs)


class DataParallelLDA:
    """AD-LDA baseline with configurable sync rate (vmap backend)."""

    def __init__(self, corpus: Corpus, num_topics: int, num_workers: int,
                 alpha: float | np.ndarray = 0.1, beta: float = 0.01,
                 seed: int = 0, syncs_per_iter: int = 1):
        corpus.validate()
        self.corpus = corpus
        self.num_topics = int(num_topics)
        self.num_workers = int(num_workers)
        self.alpha = jnp.full((num_topics,), alpha, jnp.float32) \
            if np.isscalar(alpha) else jnp.asarray(alpha, jnp.float32)
        self.beta = float(beta)
        self.vbeta = float(beta * corpus.vocab_size)
        self.syncs_per_iter = int(syncs_per_iter)
        self._rng = np.random.default_rng(seed)
        self._build(seed)
        self.iteration_count = 0

    def _build(self, seed: int) -> None:
        c, m, k, s = (self.corpus, self.num_workers, self.num_topics,
                      self.syncs_per_iter)
        shards = [worker_shard(c, w, m) for w in range(m)]
        self.shards = shards
        cap = max(1, -(-max(sh.word.shape[0] for sh in shards) // s))
        self.capacity = cap
        doc = np.zeros((m, s, cap), np.int32)
        word = np.zeros((m, s, cap), np.int32)
        mask = np.zeros((m, s, cap), bool)
        z0 = self._rng.integers(0, k, size=c.num_tokens).astype(np.int32)
        zarr = np.zeros((m, s, cap), np.int32)
        for w, sh in enumerate(shards):
            n = sh.word.shape[0]
            flat_doc = np.zeros(s * cap, np.int32)
            flat_word = np.zeros(s * cap, np.int32)
            flat_z = np.zeros(s * cap, np.int32)
            flat_mask = np.zeros(s * cap, bool)
            flat_doc[:n] = sh.doc_local
            flat_word[:n] = sh.word
            flat_z[:n] = z0[sh.token_id]
            flat_mask[:n] = True
            doc[w] = flat_doc.reshape(s, cap)
            word[w] = flat_word.reshape(s, cap)
            zarr[w] = flat_z.reshape(s, cap)
            mask[w] = flat_mask.reshape(s, cap)
        dloc = shards[0].num_local_docs
        cdk = np.zeros((m, dloc, k), np.int32)
        ckt_g = np.zeros((c.vocab_size, k), np.int32)
        for w, sh in enumerate(shards):
            zz = z0[sh.token_id]
            np.add.at(cdk[w], (sh.doc_local, zz), 1)
            np.add.at(ckt_g, (sh.word, zz), 1)
        ck_g = ckt_g.sum(axis=0).astype(np.int32)
        self.doc, self.word, self.mask = (jnp.asarray(doc), jnp.asarray(word),
                                          jnp.asarray(mask))
        self.z = jnp.asarray(zarr)
        self.cdk = jnp.asarray(cdk)
        self.ckt_global = jnp.asarray(ckt_g)
        self.ck_global = jnp.asarray(ck_g)
        self.ckt_local = jnp.broadcast_to(self.ckt_global, (m,) + ckt_g.shape)
        self.ck_local = jnp.broadcast_to(self.ck_global, (m, k))

    def step(self) -> None:
        m, s, cap = self.num_workers, self.syncs_per_iter, self.capacity
        u = jnp.asarray(self._rng.random((m, s, cap), np.float32))
        out = _iteration_dp(self.cdk, self.ckt_local, self.ck_local,
                            self.ckt_global, self.ck_global,
                            self.doc, self.word, self.z, self.mask, u,
                            self.alpha, jnp.float32(self.beta),
                            jnp.float32(self.vbeta),
                            syncs_per_iter=s)
        (self.cdk, self.ckt_local, self.ck_local,
         self.ckt_global, self.ck_global, self.z, errs) = out
        self.last_staleness_error = float(np.asarray(errs).mean())
        self.iteration_count += 1

    def run(self, num_iterations: int,
            callback: Optional[Callable[[int, "DataParallelLDA"], None]] = None,
            eval_every: int = 1) -> List[dict]:
        history = []
        for i in range(num_iterations):
            self.step()
            if (i + 1) % eval_every == 0:
                history.append({"iteration": self.iteration_count,
                                "log_likelihood": self.log_likelihood()})
            if callback is not None:
                callback(i, self)
        return history

    def gather_counts(self) -> CountState:
        cdk_full = np.zeros((self.corpus.num_docs, self.num_topics), np.int32)
        cdk = np.asarray(self.cdk)
        for w, sh in enumerate(self.shards):
            real = sh.doc_global >= 0
            cdk_full[sh.doc_global[real]] = cdk[w][:real.sum()]
        return CountState(jnp.asarray(cdk_full), self.ckt_global,
                          self.ck_global)

    def log_likelihood(self) -> float:
        state = self.gather_counts()
        lw = word_log_likelihood(state.ckt, state.ck, self.beta)
        ld = doc_log_likelihood(state.cdk, self.alpha)
        return float(lw + ld)

    def model_error(self) -> float:
        """Normalized ℓ1 staleness of local model copies at the moment of the
        last reconciliation — the parallelization error the paper's design
        eliminates (compare Fig 3: the MP engine's ``delta_error``)."""
        return getattr(self, "last_staleness_error", 0.0)
