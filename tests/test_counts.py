"""Count-state construction and invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.counts import build_counts, check_invariants, model_bytes


@given(st.integers(1, 500), st.integers(1, 30), st.integers(1, 20),
       st.integers(2, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_build_counts_invariants(n, d, v, k, seed):
    rng = np.random.default_rng(seed)
    doc = rng.integers(0, d, n)
    word = rng.integers(0, v, n)
    z = rng.integers(0, k, n)
    state = build_counts(doc, word, z, d, v, k)
    check_invariants(state, n)
    # row sums
    np.testing.assert_array_equal(np.asarray(state.cdk).sum(axis=1),
                                  np.bincount(doc, minlength=d))
    np.testing.assert_array_equal(np.asarray(state.ckt).sum(axis=1),
                                  np.bincount(word, minlength=v))


def test_model_bytes_scaling():
    per1, total = model_bytes(2_500_000, 10_000, num_workers=1)
    per64, _ = model_bytes(2_500_000, 10_000, num_workers=64)
    assert total == per1 == 2_500_000 * 10_000 * 4
    # the paper's Fig-4a 1/M memory law, at the engine's padded
    # (ceil-row) block size
    assert per64 == -(-2_500_000 // 64) * 10_000 * 4
    # pipelining S blocks per worker shrinks the resident block S-fold
    per64x4, _ = model_bytes(2_500_000, 10_000, num_workers=64,
                             blocks_per_worker=4)
    assert per64x4 == -(-2_500_000 // 256) * 10_000 * 4
