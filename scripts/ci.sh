#!/usr/bin/env bash
# Tier-1 verification entry point (ROADMAP.md): run the full test suite
# with src/ on PYTHONPATH.  Extra pytest args pass through, e.g.
#   scripts/ci.sh -m "not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Pass 1: full suite.  conftest.py fakes 4 host devices when XLA_FLAGS
# carries no explicit count, so shard_map tests run in-process here too.
python -m pytest -x -q "$@"

# Pass 2: the engine equivalence harness under an EXPLICIT 4-device host —
# guards the hybrid 2D (data, model) shard_map path even in environments
# whose ambient XLA_FLAGS would otherwise pin a different device count.
XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -x -q tests/test_engine_2d.py tests/test_engine_blocks.py

# Pass 3: seeded statistical stage — the slow-marked MH-vs-exact chain
# equivalence bounds (chi-square/tolerance, DESIGN.md §9) with the hash
# seed and the 4-device host pinned, so the declared flaky-tolerance
# bounds are exercised deterministically rather than sampled.  Only the
# `slow` marker runs here: pass 1 already covers the fast structural
# tests, and all chain randomness flows from numpy Generator(seed)
# streams pinned inside the tests.
PYTHONHASHSEED=0 \
    XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m pytest -x -q -m slow tests/test_mh_stats.py

# Pass 4: end-to-end engine throughput smoke — one tiny workload through
# benchmarks/bench_e2e.py (table-lifetime A/B on the MH pair, donation
# assertions, whole-iteration timing), so the e2e benchmark path and the
# traveling-table engine configuration it exercises can never rot
# silently.  Smoke mode writes results/bench_e2e_smoke.json only; the
# recorded perf trajectory (BENCH_e2e.json) is full-mode output.
python -m benchmarks.bench_e2e --smoke

# Pass 5: train -> snapshot -> serve smoke (DESIGN.md §11).  A 2-iter
# training run exports a frozen snapshot (reporting held-out
# doc-completion perplexity along the way), lda_infer serves a query
# batch from it (exits non-zero on non-finite perplexity), and the
# serving benchmark runs its smoke workload — the full query path from
# CLI to fold-in kernel exercised on every CI run.
SNAP_DIR="$(mktemp -d)"
python -m repro.launch.lda_infer --queries 6 --query-len 16 --sweeps 3 \
    --docs 48 --vocab 96 --topics 8 --train-iters 2
python -m repro.launch.lda_train --docs 48 --vocab 96 --topics 8 \
    --workers 2 --iters 2 --eval-holdout 8 --snapshot-out "$SNAP_DIR/snap.npz"
python -m repro.launch.lda_infer --snapshot "$SNAP_DIR/snap.npz" \
    --queries 8 --query-len 24 --sweeps 3
rm -rf "$SNAP_DIR"
python -m benchmarks.bench_infer --smoke

# Pass 6: hybrid sparse family smoke (DESIGN.md §12) — pinned-seed
# 4-device hybrid-grid training with the sparse sampler (train ->
# snapshot -> sparse fold-in serve through the CLI), then the sparse
# regime-map benchmark on its tiny CI cell.  Guards the whole §12
# surface: registry resolution with static sampler args, the 2D
# shard_map path, snapshot sparse state, and the serving alias.
SPARSE_DIR="$(mktemp -d)"
PYTHONHASHSEED=0 XLA_FLAGS="--xla_force_host_platform_device_count=4" \
    python -m repro.launch.lda_train --docs 48 --vocab 96 --topics 8 \
    --workers 2 --data-parallel 2 --iters 2 --seed 3 --sampler sparse \
    --eval-holdout 8 --holdout-sampler sparse \
    --snapshot-out "$SPARSE_DIR/snap.npz"
PYTHONHASHSEED=0 \
    python -m repro.launch.lda_infer --snapshot "$SPARSE_DIR/snap.npz" \
    --sampler sparse --queries 8 --query-len 24 --sweeps 3
rm -rf "$SPARSE_DIR"
python -m benchmarks.bench_sparse --smoke

# Pass 7: out-of-core streaming + bit-exact resume smoke (DESIGN.md §13).
# Shard a corpus to disk, train the streaming engine 2 iterations with
# per-iteration checkpoints, then "crash" and resume the run to 4
# iterations from the workdir alone (no corpus flags — geometry, sampler
# and rng all come from the checkpoint), export a SHARDED serving
# snapshot, and serve it row-restricted through lda_infer
# --snapshot-dir.  --sampler auto also exercises the measured regime
# map's (K, doc-len) lookup on a real manifest.
STREAM_DIR="$(mktemp -d)"
python -m repro.data.stream --out "$STREAM_DIR/corpus" --zipf 1.1 \
    --docs 64 --vocab 128 --doc-len 24 --shards 4 --seed 11
python -m repro.launch.lda_train --corpus-dir "$STREAM_DIR/corpus" \
    --workdir "$STREAM_DIR/run" --topics 8 --workers 2 \
    --blocks-per-worker 2 --iters 2 --sampler auto --checkpoint-every 1
python -m repro.launch.lda_train --workdir "$STREAM_DIR/run" --resume \
    --iters 4 --checkpoint-every 2 --snapshot-dir "$STREAM_DIR/snap"
python -m repro.launch.lda_infer --snapshot-dir "$STREAM_DIR/snap" \
    --queries 8 --query-len 16 --sweeps 3 --sampler scan
rm -rf "$STREAM_DIR"

# Pass 8: serving-scheduler traffic-replay smoke (DESIGN.md §14).  Shard
# a corpus, train the streaming engine twice to two SHARDED snapshots
# (earlier + later iterations of one run), then replay a seeded
# open-loop Poisson trace through lda_serve with a mid-replay hot-swap
# between them — exits non-zero if any admitted request is dropped, p99
# is non-finite, or the post-swap epoch never serves.  Both snapshot
# directories are row-restricted with the SAME word set, so the swap
# stays a pointer flip.  Then the scheduler benchmark's smoke workload
# (saturation + latency phases, .npz snapshots, warm-bucket precompile).
SERVE_DIR="$(mktemp -d)"
python -m repro.data.stream --out "$SERVE_DIR/corpus" --zipf 1.1 \
    --docs 64 --vocab 128 --doc-len 24 --shards 4 --seed 11
python -m repro.launch.lda_train --corpus-dir "$SERVE_DIR/corpus" \
    --workdir "$SERVE_DIR/run" --topics 8 --workers 2 --iters 2 \
    --checkpoint-every 2 --snapshot-dir "$SERVE_DIR/snapA"
python -m repro.launch.lda_train --workdir "$SERVE_DIR/run" --resume \
    --iters 4 --checkpoint-every 2 --snapshot-dir "$SERVE_DIR/snapB"
python -m repro.launch.lda_serve --snapshot-dir "$SERVE_DIR/snapA" \
    --swap-snapshot-dir "$SERVE_DIR/snapB" --swap-after 12 \
    --requests 32 --rate 400 --max-len 16 --sweeps 3 --seed 0
rm -rf "$SERVE_DIR"
python -m benchmarks.bench_serve --smoke

# Pass 9: fault-injection + crash-recovery smoke (DESIGN.md §15).  A
# reference streaming run trains uninterrupted to 4 iterations; a second
# run gets a scripted crash (REPRO_FAULT_PLAN kills it at the start of
# iteration 3, the in-process model of SIGKILL) under
# `lda_train --supervise`, which quarantines debris and auto-resumes
# from the last good checkpoint.  The two workdirs must then be
# BITWISE equal: counts, every assignment, and the rng bit-generator
# state — recovery is invisible, not approximate.  Then the scheduler
# rides through a dead replica: lda_serve with replica 0 scripted to
# fail every dispatch must answer 100% of admitted queries (it exits
# non-zero on any drop) and prints the breaker/fault counters.
FT_DIR="$(mktemp -d)"
python -m repro.data.stream --out "$FT_DIR/corpus" --zipf 1.1 \
    --docs 64 --vocab 128 --doc-len 24 --shards 4 --seed 11
python -m repro.launch.lda_train --corpus-dir "$FT_DIR/corpus" \
    --workdir "$FT_DIR/run_ref" --topics 8 --workers 2 --iters 4 \
    --checkpoint-every 1 --sampler scan
REPRO_FAULT_PLAN='{"format":"fault-plan-v1","seed":0,"specs":[{"kind":"crash","point":"step","match":"iter:2,","nth":1,"arg":0.0}]}' \
    python -m repro.launch.lda_train --corpus-dir "$FT_DIR/corpus" \
    --workdir "$FT_DIR/run_crash" --topics 8 --workers 2 --iters 4 \
    --checkpoint-every 1 --sampler scan --supervise --max-restarts 2
python - "$FT_DIR/run_ref" "$FT_DIR/run_crash" << 'PYEOF'
import sys
import numpy as np
from repro.core.engine.streaming import StreamingLDA
ref = StreamingLDA.resume(sys.argv[1])
rec = StreamingLDA.resume(sys.argv[2])
assert ref.iteration_count == rec.iteration_count == 4, \
    (ref.iteration_count, rec.iteration_count)
sa, sb = ref.gather_counts(), rec.gather_counts()
for name in ("cdk", "ckt", "ck"):
    np.testing.assert_array_equal(np.asarray(getattr(sa, name)),
                                  np.asarray(getattr(sb, name)),
                                  err_msg=f"{name} diverged")
np.testing.assert_array_equal(ref.assignments(), rec.assignments(),
                              err_msg="assignments diverged")
assert ref._rng.bit_generator.state == rec._rng.bit_generator.state, \
    "rng state diverged"
print("bitwise: crashed+supervised chain == uninterrupted chain")
PYEOF
python -m repro.launch.lda_train --workdir "$FT_DIR/run_crash" --resume \
    --iters 4 --snapshot-dir "$FT_DIR/snap"
python -m repro.launch.lda_serve --snapshot-dir "$FT_DIR/snap" \
    --replicas 2 --inject-replica-fail 0 --breaker-cooldown 0.05 \
    --requests 32 --rate 400 --max-len 16 --sweeps 3 --seed 0
rm -rf "$FT_DIR"

# Pass 10: pluggable CountStore smoke (DESIGN.md §16).  Two streaming
# pipelines over the same Zipf corpus — store=dense vs store=tail (K=64
# so wcap=32 head rows actually occur) — each: train 2 iters with the
# sparse sampler, checkpoint, resume to 4 iters, export a sharded
# snapshot, serve it row-restricted through lda_infer.  The two chains
# must be BITWISE equal (counts, assignments, rng state) and the tail
# run's block files must really be store-v2 .npz records — the
# store-invariance contract exercised end to end through the CLI.
CS_DIR="$(mktemp -d)"
python -m repro.data.stream --out "$CS_DIR/corpus" --zipf 1.1 \
    --docs 64 --vocab 128 --doc-len 24 --shards 4 --seed 11
for S in dense tail; do
    python -m repro.launch.lda_train --corpus-dir "$CS_DIR/corpus" \
        --workdir "$CS_DIR/run_$S" --topics 64 --workers 2 \
        --blocks-per-worker 2 --iters 2 --sampler sparse --store "$S" \
        --eval-every 0 --checkpoint-every 1
    python -m repro.launch.lda_train --workdir "$CS_DIR/run_$S" --resume \
        --iters 4 --eval-every 0 --checkpoint-every 2 \
        --snapshot-dir "$CS_DIR/snap_$S"
    python -m repro.launch.lda_infer --snapshot-dir "$CS_DIR/snap_$S" \
        --queries 8 --query-len 16 --sweeps 3 --sampler scan
done
python - "$CS_DIR" << 'PYEOF'
import glob, json, os, sys
import numpy as np
from repro.core.engine.streaming import StreamingLDA
root = sys.argv[1]
a = StreamingLDA.resume(os.path.join(root, "run_dense"))
b = StreamingLDA.resume(os.path.join(root, "run_tail"))
assert (a.store_kind, b.store_kind) == ("dense", "tail")
assert glob.glob(os.path.join(root, "run_tail", "state", "blocks",
                              "*.npz")), "tail run wrote no .npz records"
assert not glob.glob(os.path.join(root, "run_tail", "state", "blocks",
                                  "*.npy")), "stale dense block files"
sa, sb = a.gather_counts(), b.gather_counts()
for name in ("cdk", "ckt", "ck"):
    np.testing.assert_array_equal(np.asarray(getattr(sa, name)),
                                  np.asarray(getattr(sb, name)),
                                  err_msg=f"{name} diverged")
np.testing.assert_array_equal(a.assignments(), b.assignments(),
                              err_msg="assignments diverged")
assert a._rng.bit_generator.state == b._rng.bit_generator.state, \
    "rng state diverged"
m1 = json.load(open(os.path.join(root, "snap_dense", "meta.json")))
m2 = json.load(open(os.path.join(root, "snap_tail", "meta.json")))
assert m1["format"] == "sharded-snapshot-v1" and m1["store"] == "dense"
assert m2["format"] == "sharded-snapshot-v2" and m2["store"] == "tail"
print("bitwise: store=tail pipeline == store=dense pipeline")
PYEOF
rm -rf "$CS_DIR"
