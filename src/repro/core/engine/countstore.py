"""Pluggable model-count storage: the ``CountStore`` interface (DESIGN.md §16).

Every layer that persists or parks word-topic count blocks — the
streaming engine's block files, engine checkpoints, sharded serving
snapshots, the host oracle's KV store — used to hard-code a dense
``[Vb, K]`` ndarray.  This module is the storage abstraction that breaks
that assumption: a :class:`CountStore` owns one block's AT-REST
representation and answers row reads, delta folds, and (de)serialization
behind a uniform interface, so the resident footprint of a block can
track its OCCUPANCY instead of ``Vb·K·4`` bytes.

Two registered implementations:

* :class:`DenseStore` — a thin wrapper around today's ``[Vb, K]`` int32
  array.  The bitwise-frozen default: its file format is the plain
  ``.npy`` + crc32 sidecar the PR-7 streaming engine already writes, so
  existing workdirs and sharded snapshots ARE DenseStore files.
* :class:`TailStore` — the hybrid dense-head/sparse-tail layout of the
  §12 sparse samplers, made persistent: per word a CSR-style padded lane
  pair ``(topics [Vb, wcap], counts [Vb, wcap])`` (ascending topic ids,
  sentinel ``K`` past the row's nnz — byte-compatible with
  ``sparse_device._extract_lanes`` output on the same row), plus an
  explicit DENSE-OVERFLOW escape hatch: rows whose nnz exceeds the lanes
  (``nnz > wcap`` — the §12 head predicate, verbatim) are stored as full
  dense rows, so no configuration of ``wcap`` can drop counts.  In the
  long-tail regime nearly all rows fit the lanes (Peacock's
  concentration observation), so resident bytes per block drop from
  ``Vb·K·4`` to ``Vb·wcap·8`` + head occupancy.

Integer exactness is the bitwise-equivalence anchor: counts are int32,
every fold is integer addition (order-free), and the head/tail split is
a pure function of the stored values — so ``from_dense``/``to_dense``
round-trips are exact and a chain run through either store is the same
chain (tests pin engine == oracle, streaming == in-memory, and
cross-store checkpoint resume draw-for-draw).

Persistence rides the §15 integrity layer: DenseStore keeps the plain
``<stem>.npy`` artifact; TailStore writes a ``<stem>.npz`` record
(format ``store-v2``) with a JSON aux header + its lane/overflow arrays.
Both are atomically published with checksum sidecars, so a torn or
bit-flipped tail-lane file raises the structured taxonomy at load
(:mod:`repro.data.integrity`) instead of poisoning a resumed chain.
:func:`load` dispatches on whichever artifact exists, which is what
makes cross-store resume and old-workdir compatibility automatic.

This module is numpy-pure (no jax import): the same code is the host
oracle's numpy mirror and the serving path's row loader.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Tuple, Type

import numpy as np

from repro.data import integrity

STORE_RECORD_FORMAT = "store-v2"

# Head/tail threshold default — numerically equal to
# sparse_device.DEFAULT_WCAP (asserted by tests); duplicated so this
# module stays importable without jax.
DEFAULT_TAIL_WCAP = 32

_STORES: Dict[str, Type["CountStore"]] = {}


def register_store(name: str):
    """Decorator registering a :class:`CountStore` subclass under ``name``."""
    def deco(cls: Type["CountStore"]):
        cls.kind = name
        _STORES[name] = cls
        return cls
    return deco


def resolve_store(name: str) -> Type["CountStore"]:
    try:
        return _STORES[name]
    except KeyError:
        raise ValueError(
            f"unknown store kind {name!r}; "
            f"registered: {sorted(_STORES)}") from None


def available_stores() -> List[str]:
    return sorted(_STORES)


class CountStore:
    """One ``[Vb, K]`` count block behind a storage-agnostic interface.

    Subclasses implement the representation; the CHAIN-facing contract
    is integer exactness — ``to_dense(from_dense(x)) == x`` bitwise and
    every delta fold is exact int32 addition."""

    kind: str = ""

    # -- construction ------------------------------------------------------
    @classmethod
    def empty(cls, vb: int, k: int, wcap: int = DEFAULT_TAIL_WCAP) \
            -> "CountStore":
        raise NotImplementedError

    @classmethod
    def from_dense(cls, dense: np.ndarray,
                   wcap: int = DEFAULT_TAIL_WCAP) -> "CountStore":
        raise NotImplementedError

    # -- views -------------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        raise NotImplementedError

    def to_dense(self) -> np.ndarray:
        """The full ``[Vb, K]`` int32 block (the explicit densify)."""
        raise NotImplementedError

    def rows(self, idx) -> np.ndarray:
        """Dense ``[len(idx), K]`` view of selected rows WITHOUT
        materializing the whole block — the row-restricted serving
        primitive."""
        raise NotImplementedError

    def col_sums(self) -> np.ndarray:
        """Per-topic totals ``[K]`` int64 (exact integer sums)."""
        raise NotImplementedError

    # -- mutation ----------------------------------------------------------
    def apply_coo(self, rows, topics, vals) -> None:
        """Fold sparse integer deltas ``counts[rows, topics] += vals``
        (duplicates accumulate).  Raises on count underflow — a negative
        count means the caller's delta stream is corrupt."""
        raise NotImplementedError

    def apply_token_delta(self, rows, z_old, z_new) -> None:
        """Fold one round's token moves: ``-1`` at ``(rows, z_old)`` and
        ``+1`` at ``(rows, z_new)`` — the store-native form of the
        engine's ``new_block = frozen + Σ(out − frozen)`` commit (exact
        integer arithmetic, so the two are equal)."""
        rows = np.asarray(rows, np.int64).ravel()
        z_old = np.asarray(z_old, np.int64).ravel()
        z_new = np.asarray(z_new, np.int64).ravel()
        self.apply_coo(np.concatenate([rows, rows]),
                       np.concatenate([z_old, z_new]),
                       np.concatenate([np.full(rows.size, -1, np.int64),
                                       np.ones(rows.size, np.int64)]))

    def add_delta(self, delta: np.ndarray) -> None:
        """Fold a dense ``[Vb, K]`` integer delta (sparse-scattered)."""
        delta = np.asarray(delta)
        rr, tt = np.nonzero(delta)
        self.apply_coo(rr, tt, delta[rr, tt])

    # -- accounting --------------------------------------------------------
    def nbytes_resident(self) -> int:
        """Actual bytes this block occupies in memory (the quantity the
        streaming memory report and the part-(f) bench record)."""
        raise NotImplementedError

    def occupancy(self) -> dict:
        """Head/tail occupancy + overflow-row counters."""
        raise NotImplementedError

    # -- wire / persistence ------------------------------------------------
    def pack(self) -> Tuple[dict, Dict[str, np.ndarray]]:
        """``(aux, arrays)``: a JSON-able header plus the store's flat
        ndarray components — the wire format a ring ppermute (or a
        checkpoint record) moves; :meth:`unpack` inverts it exactly."""
        raise NotImplementedError

    @classmethod
    def unpack(cls, aux: dict, arrays: Dict[str, np.ndarray]) \
            -> "CountStore":
        raise NotImplementedError

    def save(self, stem: str) -> str:
        """Publish this block at ``stem`` (extension chosen by the
        implementation) atomically with a §15 checksum sidecar, removing
        any other-kind artifact at the same stem (cross-store
        migration leaves exactly one representation)."""
        aux, arrays = self.pack()
        aux = dict(aux)
        aux["format"] = STORE_RECORD_FORMAT
        path = stem + ".npz"
        integrity.save_npz(
            path,
            store_json=np.frombuffer(json.dumps(aux).encode(), np.uint8),
            **arrays)
        _remove_artifact(stem + ".npy")
        return path


def _remove_artifact(path: str) -> None:
    for p in (path, integrity.sidecar_path(path)):
        if os.path.exists(p):
            os.remove(p)


def unpack_record(aux: dict, arrays: Dict[str, np.ndarray]) -> CountStore:
    """Rebuild a store from a packed ``(aux, arrays)`` record (any
    registered kind — the checkpoint/snapshot decode path)."""
    return resolve_store(aux["kind"]).unpack(aux, arrays)


def exists(stem: str) -> bool:
    return os.path.exists(stem + ".npy") or os.path.exists(stem + ".npz")


def load(stem: str) -> CountStore:
    """Load the block stored at ``stem``, dispatching on the artifact
    present: ``<stem>.npy`` is a DenseStore (the PR-7 on-disk format,
    loadable unchanged), ``<stem>.npz`` a ``store-v2`` record of any
    registered kind.  Integrity violations raise the §15 taxonomy."""
    npy = stem + ".npy"
    if os.path.exists(npy):
        return DenseStore(integrity.load_npy(npy))
    npz = stem + ".npz"
    if os.path.exists(npz):
        data = integrity.load_npz(npz)
        try:
            aux = json.loads(bytes(data["store_json"]).decode())
        except KeyError:
            raise integrity.CorruptArtifactError(
                npz, f"not a {STORE_RECORD_FORMAT} record "
                "(missing store_json header)") from None
        if aux.get("format") != STORE_RECORD_FORMAT:
            raise ValueError(
                f"{npz}: unknown store record format "
                f"{aux.get('format')!r}; expected {STORE_RECORD_FORMAT!r}")
        arrays = {k: np.asarray(v) for k, v in data.items()
                  if k != "store_json"}
        return unpack_record(aux, arrays)
    raise integrity.MissingArtifactError(
        stem, "no count-store artifact (.npy/.npz)")


# ---------------------------------------------------------------------------
# DenseStore — the bitwise-frozen default
# ---------------------------------------------------------------------------

@register_store("dense")
class DenseStore(CountStore):
    """Thin wrapper around the dense ``[Vb, K]`` int32 block."""

    def __init__(self, arr: np.ndarray):
        self.arr = np.asarray(arr, np.int32)
        if self.arr.ndim != 2:
            raise ValueError(f"block must be [Vb, K], got {self.arr.shape}")

    @classmethod
    def empty(cls, vb, k, wcap=DEFAULT_TAIL_WCAP):
        return cls(np.zeros((vb, k), np.int32))

    @classmethod
    def from_dense(cls, dense, wcap=DEFAULT_TAIL_WCAP):
        return cls(np.array(dense, np.int32, copy=True))

    @property
    def shape(self):
        return self.arr.shape

    def to_dense(self):
        return self.arr

    def rows(self, idx):
        return self.arr[np.atleast_1d(np.asarray(idx, np.int64))]

    def col_sums(self):
        return self.arr.sum(axis=0, dtype=np.int64)

    def apply_coo(self, rows, topics, vals):
        rows = np.asarray(rows, np.int64).ravel()
        topics = np.asarray(topics, np.int64).ravel()
        vals = np.asarray(vals, np.int64).ravel()
        np.add.at(self.arr, (rows, topics), vals.astype(np.int32))
        if vals.size and (self.arr[rows, topics] < 0).any():
            raise ValueError("count underflow in DenseStore.apply_coo")

    def nbytes_resident(self):
        return int(self.arr.nbytes)

    def occupancy(self):
        vb, k = self.arr.shape
        return {"kind": self.kind, "rows": vb,
                "head_rows": vb, "tail_rows": 0, "overflow_rows": 0,
                "tail_nnz": int((self.arr > 0).sum()),
                "nbytes_resident": self.nbytes_resident(),
                "dense_bytes": vb * k * 4}

    def pack(self):
        vb, k = self.arr.shape
        return {"kind": self.kind, "vb": vb, "k": k}, {"dense": self.arr}

    @classmethod
    def unpack(cls, aux, arrays):
        return cls(arrays["dense"])

    def save(self, stem):
        # the plain-.npy artifact keeps dense block files byte-identical
        # to the pre-store streaming format (old workdirs stay loadable,
        # new dense runs stay byte-comparable)
        path = stem + ".npy"
        integrity.save_npy(path, self.arr)
        _remove_artifact(stem + ".npz")
        return path


# ---------------------------------------------------------------------------
# TailStore — dense head / sparse tail with an overflow escape hatch
# ---------------------------------------------------------------------------

@register_store("tail")
class TailStore(CountStore):
    """Hybrid lane-layout block: ``wcap`` CSR-padded lanes per row, dense
    overflow rows for ``nnz > wcap`` (the §12 head predicate).

    Internal state:

    * ``tail_topics``/``tail_counts`` [Vb, wcap] int32 — ascending topic
      ids (sentinel ``K``) and their counts, for TAIL rows; head rows
      keep all-sentinel lanes (no stale shadow data — ``col_sums`` and
      the device operand build rely on it).
    * ``_over`` dict ``row -> [K] int32`` — the overflow escape hatch.
    """

    def __init__(self, shape: Tuple[int, int], wcap: int,
                 tail_topics: np.ndarray, tail_counts: np.ndarray,
                 over: Dict[int, np.ndarray]):
        self._shape = (int(shape[0]), int(shape[1]))
        self.wcap = int(wcap)
        self.tail_topics = np.asarray(tail_topics, np.int32)
        self.tail_counts = np.asarray(tail_counts, np.int32)
        self._over = {int(r): np.asarray(v, np.int32)
                      for r, v in over.items()}

    @classmethod
    def empty(cls, vb, k, wcap=DEFAULT_TAIL_WCAP):
        wcap = max(1, min(int(k), int(wcap)))
        return cls((vb, k), wcap,
                   np.full((vb, wcap), k, np.int32),
                   np.zeros((vb, wcap), np.int32), {})

    @classmethod
    def from_dense(cls, dense, wcap=DEFAULT_TAIL_WCAP):
        dense = np.asarray(dense, np.int32)
        vb, k = dense.shape
        st = cls.empty(vb, k, wcap)
        if vb:
            chunk = st._row_chunk()
            for c0 in range(0, vb, chunk):
                idx = np.arange(c0, min(c0 + chunk, vb), dtype=np.int64)
                st._set_rows(idx, dense[c0:c0 + chunk])
        return st

    def _row_chunk(self) -> int:
        # bound transient dense [chunk, K] buffers to ~16 MiB
        return max(1, (1 << 22) // max(1, self._shape[1]))

    @property
    def shape(self):
        return self._shape

    @property
    def over_rows(self) -> np.ndarray:
        return np.array(sorted(self._over), np.int64)

    # -- row classification (the single writer) ----------------------------
    def _set_rows(self, idx: np.ndarray, dense: np.ndarray) -> None:
        """Install dense row values for ``idx``, re-deciding head/tail
        per row: ``nnz > wcap`` rows go dense into the overflow dict
        (lanes cleared to sentinel), the rest get ascending-topic lanes
        — the exact classification the §12 sampler derives from frozen
        counts, so store-native sweeps see the same split."""
        idx = np.asarray(idx, np.int64)
        dense = np.asarray(dense, np.int32)
        n = idx.size
        k, wcap = self._shape[1], self.wcap
        nnz = (dense > 0).sum(axis=1)
        head = nnz > wcap
        lanes_t = np.full((n, wcap), k, np.int32)
        lanes_c = np.zeros((n, wcap), np.int32)
        tail_nnz = np.where(head, 0, nnz)
        if tail_nnz.any():
            rr, tt = np.nonzero(np.where(head[:, None], 0, dense))
            starts = np.zeros(n + 1, np.int64)
            np.cumsum(tail_nnz, out=starts[1:])
            pos = np.arange(rr.size) - starts[rr]
            lanes_t[rr, pos] = tt
            lanes_c[rr, pos] = dense[rr, tt]
        self.tail_topics[idx] = lanes_t
        self.tail_counts[idx] = lanes_c
        for i, r in enumerate(idx):
            r = int(r)
            if head[i]:
                self._over[r] = dense[i].copy()
            else:
                self._over.pop(r, None)

    # -- views -------------------------------------------------------------
    def rows(self, idx):
        idx = np.atleast_1d(np.asarray(idx, np.int64))
        n, k = idx.size, self._shape[1]
        out = np.zeros((n, k), np.int32)
        tt = self.tail_topics[idx]
        val = tt < k
        ri = np.broadcast_to(np.arange(n)[:, None], tt.shape)
        out[ri[val], tt[val]] = self.tail_counts[idx][val]
        for i, r in enumerate(idx):
            o = self._over.get(int(r))
            if o is not None:
                out[i] = o
        return out

    def to_dense(self):
        vb = self._shape[0]
        return self.rows(np.arange(vb, dtype=np.int64))

    def col_sums(self):
        k = self._shape[1]
        out = np.zeros(k, np.int64)
        val = self.tail_topics < k
        np.add.at(out, self.tail_topics[val].astype(np.int64),
                  self.tail_counts[val].astype(np.int64))
        for o in self._over.values():
            out += o.astype(np.int64)
        return out

    # -- mutation ----------------------------------------------------------
    def apply_coo(self, rows, topics, vals):
        rows = np.asarray(rows, np.int64).ravel()
        topics = np.asarray(topics, np.int64).ravel()
        vals = np.asarray(vals, np.int64).ravel()
        if not rows.size:
            return
        order = np.argsort(rows, kind="stable")
        rs, ts, vs = rows[order], topics[order], vals[order]
        touched = np.unique(rs)
        chunk = self._row_chunk()
        for c0 in range(0, touched.size, chunk):
            cr = touched[c0:c0 + chunk]
            lo = np.searchsorted(rs, cr[0], "left")
            hi = np.searchsorted(rs, cr[-1], "right")
            dense_c = self.rows(cr)
            local = np.searchsorted(cr, rs[lo:hi])
            np.add.at(dense_c, (local, ts[lo:hi]), vs[lo:hi].astype(np.int32))
            if (dense_c < 0).any():
                raise ValueError("count underflow in TailStore.apply_coo")
            self._set_rows(cr, dense_c)

    # -- accounting --------------------------------------------------------
    def nbytes_resident(self):
        return int(self.tail_topics.nbytes + self.tail_counts.nbytes
                   + sum(o.nbytes for o in self._over.values())
                   + 8 * len(self._over))

    def occupancy(self):
        vb, k = self._shape
        h = len(self._over)
        return {"kind": self.kind, "rows": vb,
                "head_rows": h, "tail_rows": vb - h, "overflow_rows": h,
                "tail_nnz": int((self.tail_topics < k).sum()),
                "nbytes_resident": self.nbytes_resident(),
                "dense_bytes": vb * k * 4}

    # -- wire / persistence ------------------------------------------------
    def pack(self):
        vb, k = self._shape
        orr = self.over_rows
        over = (np.stack([self._over[int(r)] for r in orr])
                if orr.size else np.zeros((0, k), np.int32))
        return ({"kind": self.kind, "vb": vb, "k": k, "wcap": self.wcap},
                {"tail_topics": self.tail_topics,
                 "tail_counts": self.tail_counts,
                 "over_rows": orr, "over": over})

    @classmethod
    def unpack(cls, aux, arrays):
        over = {int(r): arrays["over"][i]
                for i, r in enumerate(np.asarray(arrays["over_rows"]))}
        return cls((aux["vb"], aux["k"]), aux["wcap"],
                   arrays["tail_topics"], arrays["tail_counts"], over)

    # -- device operand build (store-native sampling) ----------------------
    def device_operands(self, hcap: int | None = None) -> Dict[str, np.ndarray]:
        """Host-side operand build for the store-native sparse sweep
        (``sparse_device.sweep_block_sparse_tail``): the lane pair as-is,
        the overflow rows stacked into ``over_pad [Hcap, K]`` (Hcap a
        power of two ≥ the head count, so jit retraces stay logarithmic
        in head growth), and ``row_map [Vb]`` with 0 for tail rows and
        ``1 + i`` pointing at overflow slot ``i`` — the indirection that
        lets every tail row share ONE dense-segment cumsum row."""
        vb, k = self._shape
        orr = self.over_rows
        h = orr.size
        if hcap is None:
            hcap = 1 << max(0, int(h - 1).bit_length()) if h > 1 else 1
        if hcap < h:
            raise ValueError(f"hcap {hcap} < head rows {h}")
        over_pad = np.zeros((hcap, k), np.int32)
        for i, r in enumerate(orr):
            over_pad[i] = self._over[int(r)]
        row_map = np.zeros(vb, np.int32)
        row_map[orr] = np.arange(1, h + 1, dtype=np.int32)
        return {"tail_topics": self.tail_topics,
                "tail_counts": self.tail_counts,
                "over_pad": over_pad, "row_map": row_map}
