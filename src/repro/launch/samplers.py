"""CLI sampler selection: registry-derived choices + the ``auto`` probe.

The launch drivers (`lda_train`, `lda_infer`) used to hard-code their
``--sampler`` choice lists, so every new registry sampler meant touching
every CLI.  Choices now come from the engine registry itself
(`engine/rounds.py`), plus the pseudo-sampler ``auto``:

* ``auto`` resolves per platform: the Pallas kernels on TPU, their jnp
  twins elsewhere.  The pairs draw identically, so ``auto`` never
  changes a chain — only which compiled form runs it.
* Off TPU, an EXPLICITLY requested ``*_pallas`` sampler runs the kernel
  in interpret mode — correct (the bit-identity tests rely on it) but
  slow at real workload sizes (the repo-root BENCH digest shows
  ``mh_pallas`` collapsing 208→36 q/s at serving batch 32 on CPU), so
  the drivers refuse it unless ``--force`` is given.
"""
from __future__ import annotations


def train_sampler_choices() -> list:
    """``--sampler`` choices for training: every registered engine
    sampler, plus ``auto``."""
    from repro.core.engine.rounds import available_samplers
    return available_samplers() + ["auto"]


def infer_sampler_choices() -> list:
    """``--sampler`` choices for fold-in/serving: ``scan``, the
    table-capable family, the sparse family, plus ``auto`` — i.e. every
    registry sampler `infer.fold_in` can run against a frozen snapshot."""
    from repro.core.engine.rounds import available_samplers, table_capable
    names = ["scan"] + [m for m in available_samplers()
                        if table_capable(m)
                        or m in ("sparse", "sparse_pallas")]
    return names + ["auto"]


def resolve_sampler_choice(name: str, *, force: bool = False,
                           auto_tpu: str = "mh_pallas",
                           auto_default: str = "mh") -> str:
    """Resolve a CLI ``--sampler`` value to a registry sampler name.

    ``auto`` picks the Pallas form on TPU and the jnp form elsewhere
    (distribution-identical either way).  An explicit ``*_pallas`` off
    TPU exits with guidance unless ``force`` — interpret mode is a
    validation vehicle, not a serving path.
    """
    import jax
    on_tpu = jax.default_backend() == "tpu"
    if name == "auto":
        return auto_tpu if on_tpu else auto_default
    if name.endswith("_pallas") and not on_tpu and not force:
        raise SystemExit(
            f"--sampler {name}: Pallas kernels run in interpret mode on "
            f"{jax.default_backend()!r} — orders of magnitude slower at "
            f"real sizes (see BENCH_e2e.json). Use --sampler auto, the "
            f"jnp twin {name.removesuffix('_pallas')!r}, or pass --force "
            f"to run interpret mode anyway.")
    return name
