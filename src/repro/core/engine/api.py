"""Public engine facade: :class:`ModelParallelLDA` (the paper's full
system, generalized to ``S`` blocks per worker — DESIGN.md §2–§3).

Example::

    lda = ModelParallelLDA(corpus, num_topics=64, num_workers=8,
                           blocks_per_worker=4)   # 32-block pipeline
    history = lda.run(num_iterations=50)
    state = lda.gather_counts()

``blocks_per_worker`` (``S``) is the model-capacity lever: the resident
word-topic block per worker is ``ceil(V / (S·M)) × K`` rows, so growing
``S`` shrinks the per-worker resident model without adding workers —
the paper's "model size exceeds any single node's RAM" claim as a tunable.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.counts import CountState
from repro.core.engine import state as engine_state
from repro.core.engine.backends import (iteration_vmap,
                                        make_shard_map_iteration)
from repro.core.likelihood import doc_log_likelihood, word_log_likelihood
from repro.data.corpus import Corpus


class ModelParallelLDA:
    """Model-parallel LDA trainer over an ``S·M``-block pipeline."""

    def __init__(self, corpus: Corpus, num_topics: int, num_workers: int,
                 alpha: float | np.ndarray = 0.1, beta: float = 0.01,
                 seed: int = 0, sampler_mode: str = "scan",
                 sync_ck: bool = True, backend: str = "vmap",
                 mesh: Optional[Mesh] = None, axis: str = "w",
                 blocks_per_worker: int = 1):
        corpus.validate()
        if blocks_per_worker < 1:
            raise ValueError(
                f"blocks_per_worker must be >= 1, got {blocks_per_worker}")
        self.corpus = corpus
        self.num_topics = int(num_topics)
        self.num_workers = int(num_workers)
        self.blocks_per_worker = int(blocks_per_worker)
        self.alpha = jnp.full((num_topics,), alpha, jnp.float32) \
            if np.isscalar(alpha) else jnp.asarray(alpha, jnp.float32)
        self.beta = float(beta)
        self.vbeta = float(beta * corpus.vocab_size)
        self.sampler_mode = sampler_mode
        self.sync_ck = bool(sync_ck)
        self.backend = backend
        self.axis = axis
        self._rng = np.random.default_rng(seed)
        self._build()
        if backend == "shard_map":
            if mesh is None:
                devs = np.array(jax.devices()[:num_workers])
                if devs.size < num_workers:
                    raise ValueError(
                        f"shard_map backend needs {num_workers} devices, "
                        f"have {len(jax.devices())}")
                mesh = Mesh(devs, (axis,))
            self.mesh = mesh
            self._iter_fn = make_shard_map_iteration(
                mesh, axis, sampler_mode, sync_ck)
        else:
            self.mesh = None
            self._iter_fn = None

    # -- construction ------------------------------------------------------
    def _build(self) -> None:
        self.layout = engine_state.build_layout(
            self.corpus, self.num_workers, self.blocks_per_worker)
        z0 = self._rng.integers(
            0, self.num_topics, size=self.corpus.num_tokens).astype(np.int32)
        self.z_init = z0
        self.state = engine_state.init_state(self.layout, self.num_topics,
                                             z0)
        self.iteration_count = 0

    # -- layout views (kept as attributes of the facade) -------------------
    @property
    def partition(self):
        return self.layout.partition

    @property
    def shards(self):
        return self.layout.shards

    @property
    def indexes(self):
        return self.layout.indexes

    @property
    def capacity(self) -> int:
        return self.layout.capacity

    @property
    def doc(self):
        return self.layout.doc

    @property
    def woff(self):
        return self.layout.woff

    @property
    def mask(self):
        return self.layout.mask

    @property
    def num_blocks(self) -> int:
        return self.layout.num_blocks

    @property
    def num_rounds(self) -> int:
        return self.layout.num_rounds

    @property
    def resident_block_rows(self) -> int:
        """``ceil(V / (S·M))`` — rows of the block a worker actively holds."""
        return self.layout.resident_block_rows

    def memory_report(self) -> dict:
        """Resident-vs-total model bytes (the paper's capacity claim)."""
        k = self.num_topics
        vb = self.resident_block_rows
        return {
            "num_workers": self.num_workers,
            "blocks_per_worker": self.blocks_per_worker,
            "num_blocks": self.num_blocks,
            "resident_block_shape": (vb, k),
            "resident_block_bytes": vb * k * 4,
            "parked_bytes_per_worker": (self.blocks_per_worker - 1)
            * vb * k * 4,
            "total_model_bytes": self.corpus.vocab_size * k * 4,
        }

    # -- stepping ----------------------------------------------------------
    def _uniforms(self) -> jax.Array:
        b, m, cap = self.num_rounds, self.num_workers, self.capacity
        u = self._rng.random((b, m, cap), np.float32)  # [rounds, workers, T]
        return jnp.asarray(u)

    def step(self) -> None:
        """Run one iteration (= S·M rounds, every token sampled once)."""
        u = self._uniforms()
        if self.backend == "vmap":
            self.state, errs = iteration_vmap(
                self.state, u, self.doc, self.woff, self.mask,
                self.alpha, jnp.float32(self.beta), jnp.float32(self.vbeta),
                sampler_mode=self.sampler_mode, sync_ck=self.sync_ck)
        else:
            s = self.state
            out = self._iter_fn(
                s.cdk, s.ckt, s.block_id, s.ck_synced, s.ck_local, s.z,
                jnp.swapaxes(u, 0, 1), self.doc, self.woff, self.mask,
                self.alpha, jnp.float32(self.beta), jnp.float32(self.vbeta))
            self.state = engine_state.MPState(*out[:6])
            errs = out[6]
        self.round_errors = np.asarray(errs).reshape(-1)
        self.iteration_count += 1

    def run(self, num_iterations: int,
            callback: Optional[Callable[[int, "ModelParallelLDA"],
                                        None]] = None,
            eval_every: int = 1) -> List[dict]:
        history = []
        for i in range(num_iterations):
            self.step()
            if (i + 1) % eval_every == 0:
                history.append({"iteration": self.iteration_count,
                                "log_likelihood": self.log_likelihood()})
            if callback is not None:
                callback(i, self)
        return history

    # -- observation -------------------------------------------------------
    def gather_counts(self) -> CountState:
        """Reassemble the global model (the KV-store "dump")."""
        return engine_state.gather_counts(self.layout, self.state,
                                          self.num_topics)

    def assignments(self) -> np.ndarray:
        """Current z in original token order."""
        return engine_state.gather_assignments(self.layout, self.state)

    def log_likelihood(self) -> float:
        state = self.gather_counts()
        lw = word_log_likelihood(state.ckt, state.ck, self.beta)
        ld = doc_log_likelihood(state.cdk, self.alpha)
        return float(lw + ld)

    def delta_error(self) -> float:
        """Mean pre-sync Δ_{r,i} over the rounds of the last iteration
        (paper Fig 3).  Falls back to the current post-sync drift if no
        iteration has run yet."""
        errs = getattr(self, "round_errors", None)
        if errs is not None and errs.size:
            return float(errs.mean())
        from repro.core.metrics import delta_error
        return delta_error(self.state.true_ck(),
                           self.state.local_ck_views())
