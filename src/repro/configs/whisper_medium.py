"""Whisper-medium [arXiv:2212.04356].

Encoder-decoder: 24 encoder + 24 decoder layers, d 1024, 16 heads,
d_ff 4096, vocab 51865.  The mel-spectrogram + conv frontend is a STUB —
``input_specs`` supplies 1500 precomputed frame embeddings (30 s of audio
after the conv stride-2), per the assignment carve-out.  Decode shapes use
the decoder with a self-attention cache of seq_len and cross-attention
over the 1500 frames; long_500k is skipped (no sub-quadratic decoder)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,            # decoder layers
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    head_dim=64,
    rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    encoder_layers=24,
    encoder_seq=1500,
    norm="layernorm",
    tie_embeddings=True,
    subquadratic_decode=False,
)
