"""Multi-block pipelined engine (``blocks_per_worker = S``) — the
decoupling of model blocks from workers (DESIGN.md §3).

Covers: (i) S=1 and S=2 bit-equivalence with the host scheduler/KV-store
oracle (the pre-refactor architecture run serially); (ii) vmap vs
shard_map bit-agreement at S ∈ {1, 2} (subprocess, multi-device);
(iii) schedule/count invariants at S ∈ {1, 2, 3} with a vocabulary that
does not divide evenly; (iv) the resident-memory claim — the per-worker
resident block is ``ceil(V/(S·M)) × K`` independent of worker count.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import schedule as sched
from repro.core.counts import build_counts, check_invariants
from repro.core.kvstore import HostModelParallelLDA
from repro.core.model_parallel import ModelParallelLDA
from test_model_parallel import _serial_replay


# ---------------------------------------------------------------------------
# (iii) schedule invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workers", [1, 2, 3, 4, 7])
@pytest.mark.parametrize("s", [1, 2, 3])
def test_pipeline_schedule_is_exact_cover(workers, s):
    """Every round's resident blocks are disjoint; every (worker, block)
    pair meets exactly once per S·M-round iteration."""
    sched.validate_schedule(workers, s)
    table = sched.schedule_table(workers, s)
    assert table.shape == (s * workers, workers)
    # each round: M distinct blocks out of S·M
    for r in range(table.shape[0]):
        assert len(set(table[r])) == workers
    # each worker: all S·M blocks exactly once
    for m in range(workers):
        assert sorted(table[:, m]) == list(range(s * workers))


@pytest.mark.parametrize("workers", [2, 3, 5])
@pytest.mark.parametrize("s", [1, 2, 3])
def test_block_for_reduces_to_paper_rotation_and_inverts(workers, s):
    for r in range(2 * s * workers):
        for w in range(workers):
            b = sched.block_for(w, r, workers, s)
            if s == 1:
                assert b == (w + r) % workers          # paper Algorithm 1
            # resident owner is the inverse on resident rounds
            assert r % s == b // workers
            assert sched.owner_for(b, r, workers, s) == w


def test_rotation_permutation_independent_of_s():
    """Only the resident block travels: the ring permutation is the same
    single-hop m -> m-1 list no matter how many blocks are parked."""
    assert sched.rotation_permutation(4) == [(0, 3), (1, 0), (2, 1), (3, 2)]


# ---------------------------------------------------------------------------
# (iii) engine invariants at S ∈ {1, 2, 3}, non-divisible vocabulary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workers,s", [(4, 1), (4, 2), (3, 3), (2, 3)])
def test_invariants_and_z_consistency_across_s(tiny_corpus, workers, s):
    corpus, _, _ = tiny_corpus                 # V=120; e.g. B=9 -> Vb=14
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=workers,
                           seed=2, blocks_per_worker=s)
    lda.run(2)
    state = lda.gather_counts()
    check_invariants(state, corpus.num_tokens)
    z = lda.assignments()
    rebuilt = build_counts(corpus.doc, corpus.word, z, corpus.num_docs,
                           corpus.vocab_size, 8)
    np.testing.assert_array_equal(np.asarray(rebuilt.ckt),
                                  np.asarray(state.ckt))
    np.testing.assert_array_equal(np.asarray(rebuilt.cdk),
                                  np.asarray(state.cdk))


@pytest.mark.parametrize("workers,s", [(4, 2), (3, 3)])
def test_parallel_equals_serial_bitexact_pipelined(tiny_corpus, workers, s):
    """The S·M-round pipeline is still exactly equal to its serial replay
    (paper §1's parallel == serial claim survives the generalization)."""
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=workers,
                           seed=11, blocks_per_worker=s)
    rng_state = lda._rng.bit_generator.state
    u = np.asarray(lda._uniforms())
    lda._rng.bit_generator.state = rng_state
    ref_cdk, ref_ckt, ref_ck, ref_z = _serial_replay(lda, u)
    lda.step()
    np.testing.assert_array_equal(np.array(lda.state.cdk), ref_cdk)
    np.testing.assert_array_equal(np.array(lda.state.ckt), ref_ckt)
    np.testing.assert_array_equal(np.array(lda.state.ck_synced), ref_ck)
    np.testing.assert_array_equal(np.array(lda.state.z), ref_z)


def test_likelihood_ascends_with_pipeline(tiny_corpus):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=5,
                           blocks_per_worker=2)
    ll0 = lda.log_likelihood()
    hist = lda.run(6)
    assert hist[-1]["log_likelihood"] > ll0 + 1000


# ---------------------------------------------------------------------------
# (i) bit-equivalence with the host scheduler/KV-store oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", [1, 2])
def test_engine_equals_host_oracle_bitexact(tiny_corpus, s):
    """The SPMD engine equals the paper's Figure-1 architecture — explicit
    Scheduler / Workers / KV store objects run serially — bit for bit,
    given the same seed (same z0, same uniform stream, same kernel,
    frozen-C_k-per-round semantics)."""
    corpus, _, _ = tiny_corpus
    eng = ModelParallelLDA(corpus, num_topics=8, num_workers=4, seed=7,
                           blocks_per_worker=s)
    host = HostModelParallelLDA(corpus, num_topics=8, num_workers=4,
                                seed=7, blocks_per_worker=s,
                                sampler="scan", ck_sync="round")
    for _ in range(2):
        eng.step()
        host.step()
    np.testing.assert_array_equal(np.asarray(eng.gather_counts().ckt),
                                  host.gather_ckt())
    np.testing.assert_array_equal(eng.assignments(), host.assignments())


# ---------------------------------------------------------------------------
# (iv) resident-memory decoupling — the paper's capacity lever
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workers,s", [(4, 1), (4, 2), (4, 3), (2, 3)])
def test_resident_block_is_v_over_sm(tiny_corpus, workers, s):
    corpus, _, _ = tiny_corpus
    lda = ModelParallelLDA(corpus, num_topics=8, num_workers=workers,
                           seed=0, blocks_per_worker=s)
    vb_expected = -(-corpus.vocab_size // (s * workers))   # ceil(V/(S·M))
    assert lda.resident_block_rows == vb_expected
    # the array the engine actually samples each round has exactly that
    # many rows — resident model per worker shrinks with S at fixed M
    assert lda.state.resident_ckt.shape == (workers, vb_expected, 8)
    rep = lda.memory_report()
    assert rep["resident_block_bytes"] == vb_expected * 8 * 4
    assert rep["num_blocks"] == s * workers


def test_backcompat_imports():
    from repro.core.model_parallel import (  # noqa: F401
        ModelParallelLDA as A, MPState as B)
    from repro.core import ModelParallelLDA as C, MPState as D  # noqa: F401
    assert A is C and B is D


# ---------------------------------------------------------------------------
# (ii) vmap vs shard_map agreement at S ∈ {1, 2} (multi-device subprocess)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro.data.synthetic import synthetic_corpus
from repro.core.model_parallel import ModelParallelLDA

corpus, _, _ = synthetic_corpus(num_docs=40, vocab_size=120, num_topics=8,
                                doc_len=30, seed=0)
for s in (1, 2):
    a = ModelParallelLDA(corpus, 8, 4, seed=1, backend="vmap",
                         blocks_per_worker=s)
    b = ModelParallelLDA(corpus, 8, 4, seed=1, backend="shard_map",
                         blocks_per_worker=s)
    for _ in range(2):
        a.step(); b.step()
    sa, sb = a.gather_counts(), b.gather_counts()
    assert (np.asarray(sa.ckt) == np.asarray(sb.ckt)).all(), f"ckt S={s}"
    assert (np.asarray(sa.cdk) == np.asarray(sb.cdk)).all(), f"cdk S={s}"
    assert (a.assignments() == b.assignments()).all(), f"z S={s}"
    assert np.allclose(a.round_errors, b.round_errors, atol=1e-6), \
        f"errs S={s}"
print("PIPELINED_SHARD_MAP_OK")
"""


@pytest.mark.slow
def test_shard_map_equals_vmap_pipelined_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PIPELINED_SHARD_MAP_OK" in out.stdout
