"""ShapeDtypeStruct stand-ins for every (architecture × input shape) pair.

``input_specs`` returns the abstract arguments the dry-run lowers against —
weak-type-correct, shardable, never allocated.  Modality stubs enter here:
whisper gets [B, 1500, d] frame embeddings, llava gets [B, 2880, d] patch
embeddings (the assignment's sanctioned frontend carve-out).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.transformer import Model


@dataclasses.dataclass
class SpecBundle:
    kind: str                 # train | prefill | decode
    args: Tuple[Any, ...]     # abstract positional args for the step fn
    text_len: int             # text tokens actually modeled


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """The {tokens, labels, ...} batch pytree for train/prefill."""
    b, t = shape.global_batch, shape.seq_len
    text_t = t
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        # patch embeds occupy the first positions of the LM context
        text_t = t - cfg.num_patch_embeds
        batch["patch_embeds"] = _sds((b, cfg.num_patch_embeds, cfg.d_model),
                                     jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.float32)
    batch["tokens"] = _sds((b, text_t), jnp.int32)
    batch["labels"] = _sds((b, text_t), jnp.int32)
    return batch


def decode_specs(cfg: ArchConfig, shape: InputShape, model: Model
                 ) -> Tuple[Any, Any, Any, Any]:
    """(caches, tokens, pos, enc_out?) abstract values for decode_step."""
    b = shape.global_batch
    caches = jax.eval_shape(
        lambda: model.init_cache(b, shape.seq_len))
    tokens = _sds((b, 1), jnp.int32)
    pos = _sds((b,), jnp.int32)
    enc_out = None
    if cfg.family == "audio":
        enc_out = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return caches, tokens, pos, enc_out


def input_specs(cfg: ArchConfig, shape: InputShape, model: Model
                ) -> SpecBundle:
    if shape.kind in ("train", "prefill"):
        batch = batch_specs(cfg, shape)
        return SpecBundle(shape.kind, (batch,),
                          batch["tokens"].shape[1])
    caches, tokens, pos, enc_out = decode_specs(cfg, shape, model)
    args = (caches, tokens, pos) + ((enc_out,) if enc_out is not None else ())
    return SpecBundle("decode", args, 1)
