"""Serving example: batched generation + the continuous-batching server.

    PYTHONPATH=src python examples/serve_lm.py [arch]
"""
import sys

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.serve_step import BatchedServer, generate

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma3-1b"
cfg = get_config(arch).reduced()
model = build_model(cfg)
params = model.init(0)
rng = np.random.default_rng(0)

# --- batched generation ---------------------------------------------------
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)))
out = generate(model, params, prompts, num_tokens=16)
print(f"generate: prompts {prompts.shape} -> {out.shape}")
assert out.shape == (4, 8 + 16)

# --- continuous-batching server --------------------------------------------
server = BatchedServer(model, params, batch_size=4, max_len=32)
slots = [server.submit(list(rng.integers(0, cfg.vocab_size, 6)))
         for _ in range(3)]
print(f"submitted 3 requests into slots {slots}")
finished = {}
for tick in range(40):
    finished.update(server.tick())
    if len(finished) == 3:
        break
print(f"finished {len(finished)} requests; lengths "
      f"{[len(v) for v in finished.values()]}")
assert len(finished) == 3
print("server OK ✓")
