"""Phi-3-mini-3.8B [arXiv:2404.14219]: 32L dense, RoPE+SwiGLU, MHA."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    source="arXiv:2404.14219",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    rope_theta=10000.0,
    norm="rms",
    tie_embeddings=False,
    subquadratic_decode=False,
)
