"""Rotation-schedule properties (paper Algorithm 1)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import schedule as sched


@given(st.integers(1, 64))
@settings(max_examples=30, deadline=None)
def test_schedule_is_latin_square(m):
    sched.validate_schedule(m)


@given(st.integers(1, 64), st.integers(0, 200))
@settings(max_examples=50, deadline=None)
def test_owner_block_inverse(m, r):
    for w in range(m):
        b = sched.block_for(w, r, m)
        assert sched.owner_for(b, r, m) == w


@given(st.integers(1, 1000), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_partition_covers_vocab(v, m):
    p = sched.partition_vocab(v, m)
    assert p.padded_vocab >= v
    assert p.block_size * m == p.padded_vocab
    words = np.arange(v)
    blocks = p.block_of_word(words)
    offs = p.word_offset_in_block(words)
    assert (blocks >= 0).all() and (blocks < m).all()
    assert (offs >= 0).all() and (offs < p.block_size).all()
    # bijection: (block, offset) identifies the word
    recon = blocks * p.block_size + offs
    np.testing.assert_array_equal(recon, words)


@given(st.integers(1, 6), st.integers(1, 12), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_schedule_2d_exact_cover(d, m, s):
    """Hybrid grid (DESIGN.md §8): every (grid position, block) pair meets
    exactly once per iteration; per round the M resident blocks are
    disjoint within each replica and ALIGNED across replicas."""
    sched.validate_schedule_2d(d, m, s)
    table = sched.schedule_table_2d(d, m, s)
    assert table.shape == (s * m, d, m)


@given(st.integers(2, 6), st.integers(2, 12), st.integers(1, 4),
       st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_schedule_2d_replicas_never_conflict(d, m, s, r):
    """No two replicas' resident blocks conflict on the model axis: in any
    round, model position m holds the SAME block in every replica (the
    data-axis psum reconciles copies of one block, never mixes two), and
    distinct model positions hold distinct blocks."""
    table = sched.schedule_table_2d(d, m, s)
    row = table[r % table.shape[0]]              # [D, M]
    for rep in range(1, d):
        np.testing.assert_array_equal(row[rep], row[0])
    assert len(set(row[0])) == m


@given(st.integers(1, 6), st.integers(1, 12), st.integers(1, 4))
@settings(max_examples=40, deadline=None)
def test_schedule_2d_reduces_to_1d(d, m, s):
    """Replica 0's schedule is exactly the 1D pipeline schedule — the 2D
    grid never perturbs the model-axis rotation."""
    np.testing.assert_array_equal(
        sched.schedule_table_2d(d, m, s)[:, 0, :],
        sched.schedule_table(m, s))


def test_rotation_permutation_is_ring():
    perm = sched.rotation_permutation(8)
    srcs = sorted(s for s, _ in perm)
    dsts = sorted(d for _, d in perm)
    assert srcs == list(range(8)) and dsts == list(range(8))
    # after 8 applications every block returns home
    loc = list(range(8))
    mapping = dict(perm)
    for _ in range(8):
        loc = [mapping[x] for x in loc]
    assert loc == list(range(8))


def test_rotation_matches_schedule_table():
    m = 6
    table = sched.schedule_table(m)
    # applying the ppermute (block moves m -> m-1) to round r's layout
    # must produce round r+1's layout
    for r in range(m - 1):
        moved = np.empty(m, int)
        for src, dst in sched.rotation_permutation(m):
            moved[dst] = table[r, src]
        np.testing.assert_array_equal(moved, table[r + 1])
