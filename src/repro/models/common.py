"""Shared building blocks for the architecture zoo: norms, activations,
RoPE, and initialization helpers.

All models are pure functions over nested-dict parameter pytrees.  Params
are stored fp32 and cast to the compute dtype (bf16 by default) at use —
standard mixed precision, matching the roofline's bf16 peak-FLOP basis.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]
COMPUTE_DTYPE = jnp.bfloat16


def cast(x: jax.Array) -> jax.Array:
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if scale is not None:
        y = y * (1.0 + scale.astype(jnp.float32))
    return y.astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array | None,
               bias: jax.Array | None, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def apply_norm(x: jax.Array, p: Params | None, kind: str) -> jax.Array:
    """kind: 'rms' | 'layernorm' | 'nonparametric' (OLMo §non-param LN)."""
    if kind == "rms":
        return rms_norm(x, p["scale"] if p else None)
    if kind == "layernorm":
        return layer_norm(x, p["scale"] if p else None,
                          p.get("bias") if p else None)
    if kind == "nonparametric":
        return layer_norm(x, None, None)
    raise ValueError(kind)


def norm_params(d: int, kind: str) -> Params:
    if kind == "rms":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if kind == "nonparametric":
        return {}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------

def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, hd]; positions: [B, T] int32."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                     # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0) -> jax.Array:
    fan_in = int(np.prod([shape[i] for i in range(len(shape))
                          if i == in_axis]))
    std = 1.0 / max(np.sqrt(fan_in), 1.0)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std)


def embed_init(key, shape) -> jax.Array:
    return jax.random.normal(key, shape, jnp.float32) * 0.02


class KeyGen:
    """Deterministic split stream for parameter init."""

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def count_params(params: Params) -> int:
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Activation sharding constraints (set by the launcher, no-op otherwise)
# ---------------------------------------------------------------------------
# GSPMD propagates sharding from weights into activations; with a
# vocab/d-sharded embedding table the gather output inherits the *weight*
# sharding and the batch axis silently de-shards downstream (observed:
# 86 GiB/device on olmo-1b train_4k).  The launcher pins the batch axes of
# activations explicitly; models call ``shard_activations`` at block
# boundaries.  See EXPERIMENTS.md §Perf iteration "activation-sharding".

_ACT_DP = None          # tuple of mesh axis names for the batch dim
_MODEL_AXIS = None      # mesh axis name for tensor-parallel dims


def set_activation_sharding(dp_axes, model_axis="model") -> None:
    global _ACT_DP, _MODEL_AXIS
    _ACT_DP = tuple(dp_axes) if dp_axes else None
    _MODEL_AXIS = model_axis


def clear_activation_sharding() -> None:
    set_activation_sharding(None)


def shard_activations(x: jax.Array) -> jax.Array:
    """Constrain [B, ...] activations: batch over the data axes."""
    if _ACT_DP is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(_ACT_DP, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_logits(x: jax.Array) -> jax.Array:
    """Constrain [B, T, V] logits: batch over data, vocab over model."""
    if _ACT_DP is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(_ACT_DP, *([None] * (x.ndim - 2)), _MODEL_AXIS)
    return jax.lax.with_sharding_constraint(x, spec)


def shard_experts(x: jax.Array) -> jax.Array:
    """Constrain [E, C, d] expert-dispatched tokens: experts over model."""
    if _ACT_DP is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(_MODEL_AXIS, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Scan unrolling (roofline accounting mode)
# ---------------------------------------------------------------------------
# XLA's cost_analysis counts a while-loop body ONCE regardless of trip
# count.  The dry-run's cost-extrapolation variants therefore lower with
# layer scans UNROLLED (L ∈ {1, 2}), making the L2−L1 delta the exact
# per-layer cost including its collectives.  Production lowering keeps
# rolled scans (compile time, memory analysis unaffected).

_SCAN_UNROLL = False


def set_scan_unroll(on: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(on)


def scan_unroll() -> bool:
    return _SCAN_UNROLL
