"""Count-matrix state for collapsed Gibbs sampling of LDA.

The "model" in the paper's sense is the pair of count matrices

  * ``cdk`` — document-topic counts  ``C_d^k``  with shape ``[D, K]``
  * ``ckt`` — word-topic counts      ``C_k^t``  stored word-major ``[V, K]``
  * ``ck``  — topic totals           ``C_k``    with shape ``[K]``

``ckt`` is the object the paper partitions into disjoint word blocks; the
word-major layout makes a block a contiguous row range, which is what both
the rotation schedule (``schedule.py``) and the Pallas kernel tile over.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CountState:
    """Pytree holding the three LDA count tensors."""

    cdk: jax.Array  # [D, K] int32
    ckt: jax.Array  # [V, K] int32 (word-major)
    ck: jax.Array   # [K]    int32

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.cdk, self.ckt, self.ck), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- convenience -----------------------------------------------------
    @property
    def num_docs(self) -> int:
        return self.cdk.shape[0]

    @property
    def vocab_size(self) -> int:
        return self.ckt.shape[0]

    @property
    def num_topics(self) -> int:
        return self.ck.shape[0]


def build_counts(docs: np.ndarray, words: np.ndarray, z: np.ndarray,
                 num_docs: int, vocab_size: int, num_topics: int) -> CountState:
    """Accumulate count matrices from token arrays (host-side, numpy)."""
    docs = np.asarray(docs)
    words = np.asarray(words)
    z = np.asarray(z)
    cdk = np.zeros((num_docs, num_topics), np.int32)
    ckt = np.zeros((vocab_size, num_topics), np.int32)
    np.add.at(cdk, (docs, z), 1)
    np.add.at(ckt, (words, z), 1)
    ck = ckt.sum(axis=0).astype(np.int32)
    return CountState(jnp.asarray(cdk), jnp.asarray(ckt), jnp.asarray(ck))


def check_invariants(state: CountState, num_tokens: int) -> None:
    """Assert the conservation laws any amount of Gibbs sampling preserves.

    * every count is non-negative;
    * ``sum_k C_d^k`` equals the number of tokens per document (constant);
    * ``sum_d C_d^k == C_k == sum_t C_k^t`` (topic totals agree);
    * total mass equals the corpus token count.
    """
    cdk = np.asarray(state.cdk)
    ckt = np.asarray(state.ckt)
    ck = np.asarray(state.ck)
    assert (cdk >= 0).all(), "negative document-topic count"
    assert (ckt >= 0).all(), "negative word-topic count"
    assert (ck >= 0).all(), "negative topic total"
    np.testing.assert_array_equal(cdk.sum(axis=0), ck,
                                  err_msg="sum_d C_dk != C_k")
    np.testing.assert_array_equal(ckt.sum(axis=0), ck,
                                  err_msg="sum_t C_kt != C_k")
    assert int(ck.sum()) == num_tokens, (
        f"total mass {int(ck.sum())} != corpus tokens {num_tokens}")


def counts_equal(a: CountState, b: CountState) -> bool:
    return (bool((np.asarray(a.cdk) == np.asarray(b.cdk)).all())
            and bool((np.asarray(a.ckt) == np.asarray(b.ckt)).all())
            and bool((np.asarray(a.ck) == np.asarray(b.ck)).all()))


def model_bytes(vocab_size: int, num_topics: int,
                num_workers: int = 1, dtype_bytes: int = 4,
                blocks_per_worker: int = 1) -> Tuple[int, int]:
    """(per-worker resident, total) bytes of the word-topic table —
    Table 1 / Fig 4a math.

    Model-parallel workers hold one ``ceil(V/(S·M))``-row block resident
    at a time (``S = blocks_per_worker`` pipelines ``S·M`` blocks through
    ``M`` workers, DESIGN.md §3) — the same padded-block size the engine
    allocates (``VocabPartition.block_size``); a data-parallel worker
    holds the full table.
    """
    total = vocab_size * num_topics * dtype_bytes
    rows = -(-vocab_size // (num_workers * blocks_per_worker))  # ceil
    per_worker = rows * num_topics * dtype_bytes
    return per_worker, total
