"""Serving-scheduler driver: replay traffic against a live, hot-swapping
topic-inference service (DESIGN.md §14).

    # serve a snapshot, replay a seeded trace, hot-swap mid-replay
    PYTHONPATH=src python -m repro.launch.lda_serve \
        --snapshot /tmp/a.npz --swap-snapshot /tmp/b.npz --swap-after 16 \
        --requests 64 --rate 200 --sampler scan

    # sharded (out-of-core) snapshots: only the rows the trace touches
    PYTHONPATH=src python -m repro.launch.lda_serve \
        --snapshot-dir /tmp/snapA --swap-snapshot-dir /tmp/snapB \
        --swap-after 16 --requests 64

    # watch a directory: pick up each new snapshot the trainer publishes
    PYTHONPATH=src python -m repro.launch.lda_serve \
        --snapshot /tmp/live/snap_0001.npz --watch /tmp/live --requests 512

Stands up a :class:`ServingScheduler` under wall time, replays a seeded
open-loop Poisson trace (`serve/traffic.py`), and reports served/s,
p50/p99 latency, cache hit rate, and the per-epoch response counts.
Exits non-zero if any admitted request went unanswered or p99 is not
finite — the CI smoke contract (`scripts/ci.sh` pass 8).

The hot-swap is the production loop in miniature: training publishes
snapshot after snapshot, the server flips pointers without dropping a
request (frozen-model serving makes the swap trivial — no KV caches to
migrate, no in-flight state to reconcile; DESIGN.md §14).  ``--swap-*``
drives one deterministic mid-replay swap for CI; ``--watch`` polls a
directory each tick and swaps whenever a newer ``.npz`` appears.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.core import faults
from repro.core.infer import (load_sharded_snapshot_meta, load_snapshot,
                              load_snapshot_rows)
from repro.data import integrity
from repro.launch.samplers import (infer_sampler_choices,
                                   resolve_sampler_choice)
from repro.serve.scheduler import ServingScheduler, WallClock
from repro.serve.traffic import poisson_trace, replay_open_loop


def _load_sharded_pair(args, trace):
    """Row-restricted views for the trace's word set.  BOTH directories
    are restricted with the SAME flat word array, so ``np.unique`` yields
    the same remap — the remapped trace is valid against either view and
    the swap stays a pointer flip."""
    lens = [len(t.tokens) for t in trace]
    flat = np.concatenate([t.tokens for t in trace])
    snap, remapped = load_snapshot_rows(args.snapshot_dir, flat)
    parts = np.split(remapped, np.cumsum(lens)[:-1])
    for t, part in zip(trace, parts):
        t.tokens = part.astype(np.int32)
    swap = None
    if args.swap_snapshot_dir:
        swap, _ = load_snapshot_rows(args.swap_snapshot_dir, flat)
    return snap, swap, flat


def _make_watcher(args, sched, flat=None):
    """Poll ``--watch`` for a snapshot newer than the one being served;
    load + hot-swap when one appears.  Throttled by the scheduler's own
    clock, so the poll cadence needs no extra timer.

    Tolerant of the trainer mid-export (§15): a candidate that fails
    integrity validation — torn ``.npz``, sharded directory whose
    ``meta.json`` hasn't landed yet (it is written LAST, atomically), a
    block file without a matching checksum — is SKIPPED this poll and
    retried on the next, without touching the serving loop or the poll
    watermark.  Only a fully-validated candidate is swapped in.

    ``flat`` is the trace's flat word array when serving row-restricted
    sharded snapshots (the candidate must be restricted with the SAME
    words so the remap matches the in-flight trace)."""
    base = args.snapshot or getattr(args, "snapshot_dir", "")
    state = {"mtime": (os.path.getmtime(base)
                       if base and os.path.exists(base) else 0.0),
             "path": os.path.abspath(base or ""),
             "last_poll": float("-inf")}
    sharded = bool(getattr(args, "snapshot_dir", ""))

    def candidates():
        """(path, mtime) of every plausible candidate under --watch:
        ``.npz`` files, or (sharded mode) subdirectories stamped by
        their ``meta.json`` publish time."""
        try:
            entries = list(os.scandir(args.watch))
        except OSError:
            return
        for e in entries:
            if sharded:
                meta = os.path.join(e.path, "meta.json")
                if e.is_dir() and os.path.exists(meta):
                    yield e.path, os.path.getmtime(meta)
            elif e.name.endswith(".npz"):
                yield e.path, e.stat().st_mtime

    def load_validated(path):
        if sharded:
            integrity.validate_tree(path)
            snap, _ = load_snapshot_rows(
                path, flat if flat is not None else np.zeros(0, np.int32))
            return snap
        return load_snapshot(path)

    def on_tick(sched_, now):
        if now - state["last_poll"] < args.watch_interval:
            return
        state["last_poll"] = now
        newest, newest_m = None, state["mtime"]
        for path, m in candidates():
            if m > newest_m and os.path.abspath(path) != state["path"]:
                newest, newest_m = path, m
        if newest is None:
            return
        try:
            epoch = sched_.swap_snapshot(load_validated(newest))
        except (integrity.IntegrityError, ValueError, OSError) as e:
            # partial or corrupt export: keep serving the old epoch and
            # leave the watermark alone so the next poll retries
            print(f"  [watch] skipped {newest}: {type(e).__name__}: {e}")
            return
        state["mtime"], state["path"] = newest_m, os.path.abspath(newest)
        print(f"  [watch] swapped to {newest} (epoch {epoch})")

    return on_tick


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--snapshot", default="",
                    help="frozen snapshot .npz (lda_train --snapshot-out)")
    ap.add_argument("--snapshot-dir", default="",
                    help="sharded snapshot directory (lda_train "
                         "--snapshot-dir); rows are loaded restricted to "
                         "the trace's word set (DESIGN.md §13)")
    ap.add_argument("--swap-snapshot", default="",
                    help="second .npz to hot-swap to mid-replay")
    ap.add_argument("--swap-snapshot-dir", default="",
                    help="second sharded snapshot directory to hot-swap to")
    ap.add_argument("--swap-after", type=int, default=-1,
                    help="hot-swap immediately before the Nth submission "
                         "(default: midpoint when a swap target is given)")
    ap.add_argument("--watch", default="",
                    help="directory to poll for newer snapshots (.npz, "
                         "or sharded directories with --snapshot-dir); "
                         "each validated new one is hot-swapped in live — "
                         "partial/corrupt exports are skipped and retried")
    ap.add_argument("--watch-interval", type=float, default=0.2,
                    help="seconds between --watch polls")
    ap.add_argument("--sampler", choices=infer_sampler_choices(),
                    default="scan")
    ap.add_argument("--force", action="store_true",
                    help="run an explicitly requested *_pallas sampler "
                         "in interpret mode off-TPU instead of refusing")
    ap.add_argument("--sweeps", type=int, default=5)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="offered load, queries/s (Poisson arrivals)")
    ap.add_argument("--max-len", type=int, default=48,
                    help="doc-length clip of the heavy-tailed trace")
    ap.add_argument("--hot-fraction", type=float, default=0.25,
                    help="fraction of requests drawn from the hot pool "
                         "(exercises the multiset cache)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-queue", type=int, default=1024)
    ap.add_argument("--batch-delay", type=float, default=0.0,
                    help="hold a partial batch at most this long (s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--breaker-threshold", type=int, default=3,
                    help="consecutive replica failures that open its "
                         "circuit breaker (DESIGN.md §15)")
    ap.add_argument("--breaker-cooldown", type=float, default=0.25,
                    help="seconds an open breaker waits before a "
                         "half-open probe")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="per-request retry budget across replicas")
    ap.add_argument("--request-deadline", type=float, default=None,
                    help="reject (structured) any admitted request "
                         "queued longer than this many seconds")
    ap.add_argument("--inject-replica-fail", type=int, default=-1,
                    metavar="R",
                    help="fault injection: replica R raises on every "
                         "dispatch — the degraded-mode smoke (breaker "
                         "opens, retries answer on the others)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if bool(args.snapshot) == bool(args.snapshot_dir):
        ap.error("exactly one of --snapshot / --snapshot-dir is required")
    if args.swap_snapshot and args.swap_snapshot_dir:
        ap.error("--swap-snapshot and --swap-snapshot-dir are mutually "
                 "exclusive")
    if args.swap_snapshot_dir and not args.snapshot_dir:
        ap.error("--swap-snapshot-dir needs --snapshot-dir (the row "
                 "restriction must share one word set)")
    if args.watch and not (args.snapshot or args.snapshot_dir):
        ap.error("--watch needs --snapshot (.npz mode) or "
                 "--snapshot-dir (sharded mode)")

    if args.snapshot_dir:
        vocab = load_sharded_snapshot_meta(args.snapshot_dir)["vocab_size"]
    else:
        snap = load_snapshot(args.snapshot)
        vocab = snap.vocab_size
    trace = poisson_trace(args.requests, args.rate, vocab, seed=args.seed,
                          max_len=args.max_len,
                          hot_fraction=args.hot_fraction)
    swap_snap, flat = None, None
    if args.snapshot_dir:
        snap, swap_snap, flat = _load_sharded_pair(args, trace)
    elif args.swap_snapshot:
        swap_snap = load_snapshot(args.swap_snapshot)
    swap_after = None
    if swap_snap is not None:
        swap_after = (args.swap_after if args.swap_after >= 0
                      else args.requests // 2)

    args.sampler = resolve_sampler_choice(
        args.sampler, force=args.force, num_topics=snap.num_topics,
        max_doc_len=args.max_len)
    print(f"serving V={snap.vocab_size:,} K={snap.num_topics} "
          f"fp={snap.fingerprint()} sampler={args.sampler} "
          f"replicas={args.replicas} max_batch={args.max_batch}")

    plan = None
    if args.inject_replica_fail >= 0:
        if args.inject_replica_fail >= args.replicas:
            ap.error(f"--inject-replica-fail {args.inject_replica_fail} "
                     f"is out of range for --replicas {args.replicas}")
        plan = faults.FaultPlan.replica_fail(args.inject_replica_fail,
                                             nth=0, seed=args.seed)
        print(f"fault injection: replica {args.inject_replica_fail} "
              "fails every dispatch")
    sched = ServingScheduler(
        snap, sampler=args.sampler, num_sweeps=args.sweeps, seed=args.seed,
        num_replicas=args.replicas, max_queue=args.max_queue,
        max_batch=args.max_batch, max_batch_delay=args.batch_delay,
        breaker_threshold=args.breaker_threshold,
        breaker_cooldown=args.breaker_cooldown,
        max_retries=args.max_retries,
        request_deadline=args.request_deadline,
        fault_plan=plan, clock=WallClock())
    buckets = sched.warm(args.max_len)   # compile outside the replay
    print(f"warmed {buckets} (batch, token) buckets")
    on_tick = _make_watcher(args, sched, flat=flat) if args.watch else None
    summary = replay_open_loop(sched, trace, swap_after=swap_after,
                               swap_snapshot=swap_snap, on_tick=on_tick)

    print(f"replayed {summary['requests']} requests in "
          f"{summary['elapsed_s']:.2f}s: {summary['served_qps']:,.1f} "
          f"served/s (offered {summary['offered_qps']:,.1f}/s)")
    print(f"latency p50 {summary['p50_ms']:.2f} ms  "
          f"p99 {summary['p99_ms']:.2f} ms; cache "
          f"{summary['cache']['hits']}/{summary['cache']['hits'] + summary['cache']['misses']} hit; "
          f"rejections {summary['rejections'] or 'none'}")
    print(f"epochs served: {summary['epochs']} over "
          f"{sched.swaps} swap(s); dropped {summary['dropped']}")
    st = sched.stats()
    print(f"faults: {st['faults']}")
    print("breakers: " + "  ".join(
        f"replica {i}: {h['state']} ({h['successes']} ok / "
        f"{h['failures']} fail, {h['opens']} open(s))"
        for i, h in enumerate(st["replicas"])))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({k: v for k, v in summary.items()}, f, indent=1,
                      default=str)
    if summary["dropped"] != 0:
        sys.exit(f"{summary['dropped']} admitted requests went "
                 "unanswered — serving smoke FAILED")
    if summary["served"] and not np.isfinite(summary["p99_ms"]):
        sys.exit("non-finite p99 latency — serving smoke FAILED")
    if swap_after is not None and len(summary["epochs"]) < 2:
        sys.exit("hot-swap never served the new epoch — smoke FAILED")


if __name__ == "__main__":
    main()
