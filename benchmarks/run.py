"""Benchmark entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark) and writes
full JSON payloads under benchmarks/results/.  After the run, every
per-benchmark result is aggregated into the repo-root ``BENCH_e2e.json``
(the e2e throughput trajectory at top level, the rest as a digest) so one
file tracks the system's perf state across PRs.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_convergence, bench_e2e, bench_error,
                        bench_kernel, bench_model_size, bench_samplers,
                        bench_scaling, bench_sparse)

BENCHES = {
    "fig2_convergence": bench_convergence.run,
    "fig3_error": bench_error.run,
    "table1_model_size": bench_model_size.run,
    "fig4_scaling": bench_scaling.run,
    "kernel_sampler": bench_kernel.run,
    "sampler_backends": bench_samplers.run,
    "sparse_regime_map": bench_sparse.run,
    "e2e_throughput": bench_e2e.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=list(BENCHES), default=None)
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            BENCHES[name]()
        except Exception:
            failures += 1
            print(f"{name},FAILED,", file=sys.stderr)
            traceback.print_exc()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    try:
        path = bench_e2e.aggregate_root()
        print(f"# aggregated results -> {path}", file=sys.stderr)
    except Exception:
        failures += 1
        traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
