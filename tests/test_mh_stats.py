"""Statistical equivalence of the O(1) alias-table MH backend.

MH draws are *distribution-equal* but not trajectory-equal to the exact
inverse-CDF chain, so — unlike every other backend pairing in this repo —
scan-vs-mh cannot be validated bitwise.  This suite grows the
verification story accordingly (DESIGN.md §9):

1. **Statistical layer** — exact-``scan`` and ``mh`` chains run from the
   same init on a small synthetic corpus; after burn-in, label-invariant
   posterior summaries must agree within calibrated bounds.  Bounds are
   *self-calibrating*: a twin chain with a different seed measures a
   sampler's own seed-to-seed spread, and the chain under test must land
   within a small multiple of it (plus an absolute floor so a degenerate
   twin distance cannot make the test vacuous).  Two claims, calibrated
   against the right twin each:

   * **topic occupancy** (sorted ``C_k`` profile) — MH vs the exact
     chain, scan-twin calibrated: the word-level posterior summaries
     agree across sampler families.
   * **doc-topic moments** — at a converged window the MH family sits at
     a small persistent offset in doc concentration vs the exact
     full-conditional chain (the LightLDA local-proposal property
     declared in DESIGN.md §9's caveat; measured ≈ 11% on this corpus),
     so the mh-vs-scan check is a drift GUARD with an explicit allowance
     for that documented offset, while the sharp twin-calibrated
     equivalence is asserted where it truly holds: between the two MH
     table lifetimes (fresh vs traveling stale tables, DESIGN.md §10),
     calibrated by the MH chain's own twin.
2. **Structural layer** — everything around the draw IS still bitwise
   testable: device MH replays draw-for-draw against the `kvstore` host
   oracle fed the same uniforms, the vmap and shard_map backends agree
   exactly, and the 2D ``(data, model)`` grid composes with MH exactly
   as with the exact samplers.

Both layers cover BOTH table lifetimes (DESIGN.md §10): the original
rebuild-per-round schedule and the amortized traveling-table schedule
(word tables built once per iteration at first residency and rotated
with their block, doc tables from iteration-start counts).  The stale
tables shift only the proposals — the acceptance keeps the chain's
invariant distribution — so the statistical bounds must hold unchanged,
and the build/rotation schedule is mirrored by the host oracle so the
bitwise replay holds at every (D, M, S) geometry.

All seeds are pinned; with hashes/seeds fixed by ``scripts/ci.sh`` the
chi-square statistics are deterministic, so the tolerance bounds are
exercised reproducibly rather than being flaky-tolerance guesses.
"""
import numpy as np
import pytest

from repro.core.engine.api import ModelParallelLDA
from repro.core.kvstore import HostModelParallelLDA
from repro.data.synthetic import synthetic_corpus

# chain geometry: ~1.2k tokens, K=8, M=2 workers -> blocks small enough
# that the MH round-start freeze window is a few hundred tokens.
#
# The statistical comparison runs on a DIFFUSE corpus (flat topics, wide
# doc-topic prior): there the posterior is weakly multimodal, both chains
# mix within the burn-in, and the twin-calibrated bounds have teeth.  On
# a strongly peaked corpus the posterior modes are far apart and a
# local-proposal MH chain can sit in a more concentrated mode than the
# exact chain for hundreds of iterations — a real property of LightLDA-
# style samplers (DESIGN.md §9), not a bug this suite could flag.
K = 8
# burn-in sized for the SLOWEST chain under test: the MH proposals are
# local, so both MH lifetimes approach the doc-concentration summaries
# more slowly than the exact full-conditional draw (DESIGN.md §9 caveat);
# by ~120 iterations the round- and iteration-lifetime chains sit on the
# same trajectory and inside the twin-calibrated bounds of the exact one.
BURN, SAMPLES = 120, 60
CHI2_999_DF7 = 24.32          # chi-square 0.999 quantile at K-1 = 7 dof


@pytest.fixture(scope="module")
def mh_corpus():
    corpus, phi, theta = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=K, doc_len=30,
        alpha=0.5, seed=0, peaked=False)
    return corpus


def _chain_stats(corpus, sampler_mode, seed, backend="vmap",
                 table_lifetime=None):
    """Run burn-in + sampling iterations; return label-invariant posterior
    summaries averaged over the sampled iterations."""
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=seed,
                           sampler_mode=sampler_mode, backend=backend,
                           table_lifetime=table_lifetime)
    alpha = np.asarray(lda.alpha)
    occ, m2, ent = [], [], []
    for it in range(BURN + SAMPLES):
        lda.step()
        if it < BURN:
            continue
        state = lda.gather_counts()
        ck = np.asarray(state.ck, np.float64)
        occ.append(np.sort(ck)[::-1] / ck.sum())
        cdk = np.asarray(state.cdk, np.float64)
        theta = (cdk + alpha) / (cdk.sum(1, keepdims=True) + alpha.sum())
        m2.append(float((theta ** 2).sum(1).mean()))
        ent.append(float(-(theta * np.log(theta)).sum(1).mean()))
    return {
        "occupancy": np.mean(occ, axis=0),      # sorted, normalized [K]
        "theta_m2": float(np.mean(m2)),         # E_d[Σ_k θ_dk²]
        "theta_entropy": float(np.mean(ent)),   # E_d[H(θ_d)]
        "tokens": float(ck.sum()),
    }


def _chi2(obs, exp, tokens):
    o = obs * tokens
    e = np.maximum(exp * tokens, 1e-9)
    return float(((o - e) ** 2 / e).sum())


@pytest.fixture(scope="module")
def scan_reference(mh_corpus):
    """The exact chain (seed 0) plus its seed-1 twin: the twin-to-reference
    distance calibrates how much two SAME-distribution chains differ."""
    ref = _chain_stats(mh_corpus, "scan", seed=0)
    twin = _chain_stats(mh_corpus, "scan", seed=1)
    return ref, twin


@pytest.fixture(scope="module")
def mh_round_reference(mh_corpus):
    """The round-lifetime MH chain (seed 0) and its seed-1 twin: the
    calibration base for the table-staleness equivalence claim — the MH
    sampler's own seed-to-seed spread, not the exact sampler's."""
    ref = _chain_stats(mh_corpus, "mh", seed=0, table_lifetime="round")
    twin = _chain_stats(mh_corpus, "mh", seed=1, table_lifetime="round")
    return ref, twin


# measured persistent doc-concentration offset of the MH family vs the
# exact chain on this corpus (≈ 11-12% across lifetimes/seeds, DESIGN.md
# §9 caveat): the guard tolerates it with modest headroom but fails if
# the offset grows by even ~30% — e.g. an acceptance-math regression
MH_DOC_MOMENT_DRIFT = 0.15


@pytest.mark.slow
@pytest.mark.parametrize("backend,lifetime", [
    ("vmap", "round"),          # fresh tables: PR-3's validated schedule
    ("vmap", "iteration"),      # stale traveling tables (DESIGN.md §10)
    ("shard_map", "iteration"),
])
def test_mh_matches_exact_chain_statistics(mh_corpus, scan_reference,
                                           backend, lifetime):
    """MH topic occupancy within the twin-calibrated chi-square/tolerance
    bounds of the exact chain, and doc-topic moments within the declared
    drift guard, on both backends and at BOTH table lifetimes."""
    ref, twin = scan_reference
    mh = _chain_stats(mh_corpus, "mh", seed=0, backend=backend,
                      table_lifetime=lifetime)

    # -- per-topic occupancy: L∞ and chi-square vs the exact chain -------
    twin_linf = np.abs(twin["occupancy"] - ref["occupancy"]).max()
    mh_linf = np.abs(mh["occupancy"] - ref["occupancy"]).max()
    assert mh_linf <= max(3.0 * twin_linf, 0.02), \
        (mh_linf, twin_linf, mh["occupancy"], ref["occupancy"])

    twin_chi2 = _chi2(twin["occupancy"], ref["occupancy"], ref["tokens"])
    mh_chi2 = _chi2(mh["occupancy"], ref["occupancy"], ref["tokens"])
    assert mh_chi2 <= max(3.0 * twin_chi2, CHI2_999_DF7), \
        (mh_chi2, twin_chi2)

    # -- doc-topic marginal moments: drift guard (module docstring) ------
    for key in ("theta_m2", "theta_entropy"):
        mh_d = abs(mh[key] - ref[key])
        bound = max(3.0 * abs(twin[key] - ref[key]),
                    MH_DOC_MOMENT_DRIFT * abs(ref[key]))
        assert mh_d <= bound, (key, mh_d, bound, mh[key], ref[key])


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["vmap", "shard_map"])
def test_stale_tables_match_round_lifetime_statistics(mh_corpus,
                                                      mh_round_reference,
                                                      backend):
    """THE statistical claim of the traveling-table schedule (ISSUE 4):
    per-iteration (stale) proposal tables leave the chain's posterior
    summaries within the MH sampler's own twin-calibrated seed-to-seed
    spread of the fresh-table chain.  Staleness shifts proposals only;
    the eq.-(1) acceptance absorbs it, so the two lifetimes must be
    statistically indistinguishable — a sharper claim than the scan
    comparison, which carries the known proposal-family offset."""
    ref, twin = mh_round_reference
    stale = _chain_stats(mh_corpus, "mh", seed=0, backend=backend,
                         table_lifetime="iteration")

    twin_linf = np.abs(twin["occupancy"] - ref["occupancy"]).max()
    stale_linf = np.abs(stale["occupancy"] - ref["occupancy"]).max()
    assert stale_linf <= max(3.0 * twin_linf, 0.02), \
        (stale_linf, twin_linf, stale["occupancy"], ref["occupancy"])

    twin_chi2 = _chi2(twin["occupancy"], ref["occupancy"], ref["tokens"])
    stale_chi2 = _chi2(stale["occupancy"], ref["occupancy"],
                       ref["tokens"])
    assert stale_chi2 <= max(3.0 * twin_chi2, CHI2_999_DF7), \
        (stale_chi2, twin_chi2)

    for key in ("theta_m2", "theta_entropy"):
        twin_d = abs(twin[key] - ref[key])
        stale_d = abs(stale[key] - ref[key])
        assert stale_d <= max(3.0 * twin_d, 0.05 * abs(ref[key])), \
            (key, stale_d, twin_d, stale[key], ref[key])


@pytest.mark.slow
def test_mh_improves_likelihood():
    """Mixing sanity on the PEAKED corpus (planted structure): the MH
    chain climbs in joint likelihood toward the structure, like the
    exact samplers do."""
    corpus, _, _ = synthetic_corpus(
        num_docs=40, vocab_size=120, num_topics=K, doc_len=30, seed=0)
    lda = ModelParallelLDA(corpus, K, num_workers=2, seed=0,
                           sampler_mode="mh")
    ll0 = lda.log_likelihood()
    lda.run(15)
    assert lda.log_likelihood() > ll0 + 0.05 * abs(ll0)


# ---------------------------------------------------------------------------
# Structural layer: bitwise anchors under the statistical claim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,s,d,lifetime", [
    (2, 1, 1, "round"),
    # traveling tables at (D, M, S) ∈ {1,2} × {2} × {1,2}: every
    # combination of pipeline depth and data replication the table
    # rotation composes with (acceptance criterion of ISSUE 4)
    (2, 1, 1, "iteration"),
    (2, 2, 1, "iteration"),
    (2, 1, 2, "iteration"),
    (2, 2, 2, "iteration"),
])
def test_mh_host_oracle_replay_draw_for_draw(mh_corpus, m, s, d, lifetime):
    """Device MH == kvstore host-oracle MH, bit for bit: both consume the
    same externally supplied uniforms through the same jitted kernel —
    and, under the iteration lifetime, the same once-per-iteration table
    build schedule — so the statistical suite rests on a replayable
    structural base."""
    lda = ModelParallelLDA(mh_corpus, K, num_workers=m, seed=0,
                           sampler_mode="mh", blocks_per_worker=s,
                           data_parallel=d, table_lifetime=lifetime)
    host = HostModelParallelLDA(mh_corpus, K, num_workers=m, seed=0,
                                sampler="mh", ck_sync="round",
                                blocks_per_worker=s, data_parallel=d,
                                table_lifetime=lifetime)
    for _ in range(2):
        lda.step()
        host.step()
    np.testing.assert_array_equal(lda.assignments(), host.assignments())
    np.testing.assert_array_equal(np.asarray(lda.gather_counts().ckt),
                                  host.gather_ckt())


@pytest.mark.parametrize("lifetime", ["round", "iteration"])
def test_mh_backends_bit_identical(mh_corpus, lifetime):
    """vmap and shard_map execute the SAME mh worker_round: bitwise equal
    states after two iterations (transfers the statistical validation to
    both backends).  Under the iteration lifetime this also proves the
    vmap ``roll`` of the packed table matches the shard_map
    ``ppermute``."""
    import jax
    if len(jax.devices()) < 2:
        pytest.skip("needs 2 devices")
    a = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", backend="vmap",
                         table_lifetime=lifetime)
    b = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", backend="shard_map",
                         table_lifetime=lifetime)
    for _ in range(2):
        a.step()
        b.step()
    for x, y in [(a.state.cdk, b.state.cdk), (a.state.ckt, b.state.ckt),
                 (a.state.ck_local, b.state.ck_local),
                 (a.state.z, b.state.z)]:
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("lifetime", ["round", "iteration"])
def test_mh_pallas_engine_equals_mh_engine(mh_corpus, lifetime):
    """The mh_pallas sampler mode is a drop-in at either table lifetime:
    same chain, bit for bit (the fused Pallas cycle == the jnp cycle)."""
    a = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", table_lifetime=lifetime)
    b = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh_pallas", table_lifetime=lifetime)
    a.step()
    b.step()
    np.testing.assert_array_equal(np.asarray(a.state.z),
                                  np.asarray(b.state.z))
    np.testing.assert_array_equal(np.asarray(a.state.ckt),
                                  np.asarray(b.state.ckt))


def test_table_lifetimes_are_distinct_chains(mh_corpus):
    """Sanity that the iteration lifetime actually changes the build
    schedule: with stale vs fresh tables the SAME uniforms must produce
    different draws somewhere in the first iteration (if they never did,
    the traveling-table machinery would be dead code)."""
    a = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", table_lifetime="iteration")
    b = ModelParallelLDA(mh_corpus, K, num_workers=2, seed=0,
                         sampler_mode="mh", table_lifetime="round")
    a.step()
    b.step()
    assert (np.asarray(a.state.z) != np.asarray(b.state.z)).any()


def test_table_lifetime_validation(mh_corpus):
    """Non-MH samplers have no proposal tables to amortize."""
    with pytest.raises(ValueError, match="table-capable"):
        ModelParallelLDA(mh_corpus, K, num_workers=2, sampler_mode="scan",
                         table_lifetime="iteration")
    with pytest.raises(ValueError, match="table-capable"):
        HostModelParallelLDA(mh_corpus, K, num_workers=2, sampler="scan",
                             ck_sync="round", table_lifetime="iteration")
