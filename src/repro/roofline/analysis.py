"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms per (arch × shape × mesh), all per-device per-step seconds:

    compute    = HLO_FLOPs / peak_FLOPs            (197 TF/s bf16, v5e)
    memory     = HLO_bytes_accessed / HBM_bw       (819 GB/s)
    collective = collective_bytes / ICI_link_bw    (~50 GB/s/link)

``cost_analysis`` counts a ``lax.scan`` body ONCE regardless of trip count,
so raw numbers for scanned layer stacks are per-layer-ish.  We correct by
layer-count extrapolation: lower the same program at L=1 and L=2 layers;
the difference is the exact per-layer cost, and

    full = cost(L=1) + (L_scan − 1) · (cost(L=2) − cost(L=1))

which is exact for homogeneous stacks (all of ours are, per scan group).
Collective bytes are parsed from the SPMD-partitioned HLO text, where
operand shapes are already per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict

# --- TPU v5e constants (per chip) ------------------------------------------
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device bytes moved by each collective family, from HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if m.group(0).endswith("-done("):
            continue  # avoid double-count of async pairs (counted at -start)
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


@dataclasses.dataclass
class RawCosts:
    flops: float             # per device (scan bodies counted once)
    bytes_accessed: float    # per device
    coll_bytes: float        # per device
    coll_detail: Dict[str, Any]


def raw_costs(compiled) -> RawCosts:
    from repro.compat import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    text = compiled.as_text()
    coll = collective_bytes(text)
    return RawCosts(float(ca.get("flops", 0.0)),
                    float(ca.get("bytes accessed", 0.0)),
                    float(coll["total_bytes"]), coll)


def extrapolate(c1: RawCosts, c2: RawCosts, scan_layers: int) -> RawCosts:
    """full = c1 + (L−1)(c2 − c1) applied term-wise (exact for homogeneous
    scan stacks; the L-independent prologue/epilogue cancels)."""
    f = c1.flops + (scan_layers - 1) * (c2.flops - c1.flops)
    b = c1.bytes_accessed + (scan_layers - 1) * (c2.bytes_accessed
                                                 - c1.bytes_accessed)
    cb = c1.coll_bytes + (scan_layers - 1) * (c2.coll_bytes - c1.coll_bytes)
    detail = {
        "bytes": {k: c1.coll_detail["bytes"][k] + (scan_layers - 1) * (
            c2.coll_detail["bytes"][k] - c1.coll_detail["bytes"][k])
            for k in c1.coll_detail["bytes"]},
        "counts": c2.coll_detail["counts"],
    }
    return RawCosts(max(f, 0.0), max(b, 0.0), max(cb, 0.0), detail)


def roofline_terms(costs: RawCosts) -> Dict[str, float]:
    compute = costs.flops / PEAK_FLOPS
    memory = costs.bytes_accessed / HBM_BW
    coll = costs.coll_bytes / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(compute, memory, coll)
    terms["bound_fraction"] = {
        k: (terms[k] / total if total else 0.0)
        for k in ("compute_s", "memory_s", "collective_s")}
    return terms


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (the "useful compute" yardstick)
# ---------------------------------------------------------------------------

def active_params(cfg) -> float:
    """Matmul-active parameters per token (MoE: routed fraction only;
    embedding lookups excluded, unembed projection included)."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    attn = d * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
    if cfg.block_pattern:          # xlstm pair: mlstm qkvo + gates + slstm
        mlstm = d * hd * cfg.num_heads * 4 + 2 * d * cfg.num_heads
        slstm = 8 * d * d
        per_pair = mlstm + slstm
        layer = per_pair
        n_layers = cfg.num_layers // len(cfg.block_pattern)
    else:
        n_layers = cfg.num_layers
        if cfg.num_experts:
            expert = 3 * d * cfg.d_ff
            routed = expert * cfg.num_experts_per_tok
            shared = 3 * d * (cfg.num_shared_experts * cfg.d_ff) \
                + d * 1 if cfg.num_shared_experts else 0
            router = d * cfg.num_experts
            ffn = routed + shared + router
        elif cfg.d_ff:
            mult = 3 if cfg.family != "audio" else 2
            ffn = mult * d * cfg.d_ff
        else:
            ffn = 0
        mamba = 0
        if cfg.family == "hybrid":
            h = cfg.ssm_heads or cfg.num_heads
            di = h * hd
            mamba = d * 2 * di + d * 2 * h * cfg.ssm_state_size \
                + d * h + di * d
        layer = attn + ffn + mamba
        if cfg.family == "audio":
            layer += attn  # cross-attention
    total = n_layers * layer + d * cfg.vocab_size   # + unembed
    if cfg.family == "audio":
        enc_layer = attn + 2 * d * cfg.d_ff
        total += cfg.encoder_layers * enc_layer
    return float(total)


def model_flops(cfg, shape) -> float:
    """6·N_active·D train / 2·N_active·D forward (global, per step)."""
    n = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq


# ---------------------------------------------------------------------------
# Analytic corrections for inner (non-layer) scans
# ---------------------------------------------------------------------------
# The L-extrapolation recovers everything that scales with the layer count,
# but the chunked-attention and SSD chunk scans INSIDE a layer are still
# counted once by cost_analysis (one [q_chunk × k_chunk] block instead of
# nq·nk blocks).  Their cost is analytically exact — the chunked
# implementations compute every (masked) block — so we add closed-form
# terms.  Methodology documented in EXPERIMENTS.md §Roofline.

def attention_correction(cfg, shape) -> Dict[str, float]:
    """Per-DEVICE flops/bytes of full-sequence attention score/PV matmuls
    (train & prefill; decode unrolls and needs no correction)."""
    if shape.kind == "decode" or cfg.block_pattern:
        return {"flops": 0.0, "bytes": 0.0}
    b, t = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        pass  # patch positions replace text positions; total length is t
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    # chunked attention computes ALL blocks (masking, not skipping)
    per_layer = 4.0 * b * h * t * t * hd          # scores + PV, fwd
    n_layers = cfg.num_layers
    if cfg.family == "audio":
        # decoder self (t×t) + cross (t×enc) + encoder self (enc×enc)
        enc = cfg.encoder_seq
        per_layer = 4.0 * b * h * hd * (t * t + t * enc)
        per_layer_enc = 4.0 * b * h * hd * enc * enc
        flops = cfg.num_layers * per_layer + cfg.encoder_layers * per_layer_enc
    else:
        flops = n_layers * per_layer
    mult = 4.0 if shape.kind == "train" else 1.0   # fwd + remat-fwd + bwd(2x)
    flops *= mult
    # HBM traffic: K and V re-read once per query block; Q/O once
    nq = max(t // 512, 1)
    kv_bytes = 2.0 * b * t * h * hd * 2            # K+V, bf16
    qo_bytes = 2.0 * b * t * h * hd * 2
    bytes_ = n_layers * (nq * kv_bytes + qo_bytes) * (3.0 if mult > 1 else 1.0)
    return {"flops": flops, "bytes": bytes_}


def ssd_correction(cfg, shape) -> Dict[str, float]:
    """Per-DEVICE flops of the SSD/mLSTM chunk scan (linear in T)."""
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    b, t = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    chunk = 128
    per_layer_extra = 0.0
    if cfg.block_pattern:                        # xlstm: mLSTM half
        h, dk, dv = cfg.num_heads, hd, hd
        layers = cfg.num_layers // len(cfg.block_pattern)
        # plus the sLSTM recurrent matmul, one [d,4d] per time step
        # (sequential scan: counted once by cost_analysis, T times real)
        d = cfg.d_model
        per_layer_extra = (2.0 * b * t * 4 * d * d
                           * (4.0 if shape.kind == "train" else 1.0))
    elif cfg.family == "hybrid":                 # hymba mamba heads
        h, dk, dv = (cfg.ssm_heads or cfg.num_heads), cfg.ssm_state_size, hd
        layers = cfg.num_layers
    else:
        return {"flops": 0.0, "bytes": 0.0}
    per_layer = 2.0 * b * t * h * (chunk * (dk + dv) + 2.0 * dk * dv)
    mult = 4.0 if shape.kind == "train" else 1.0
    flops = layers * (per_layer * mult + per_layer_extra)
    bytes_ = layers * 4.0 * b * t * h * (dk + dv) * 2 * (3.0 if mult > 1 else 1)
    return {"flops": flops, "bytes": bytes_}


def inner_scan_corrections(cfg, shape, devices: int) -> Dict[str, float]:
    """Global->per-device analytic correction to add to extrapolated costs."""
    a = attention_correction(cfg, shape)
    s = ssd_correction(cfg, shape)
    return {"flops": (a["flops"] + s["flops"]) / devices,
            "bytes": (a["bytes"] + s["bytes"]) / devices}
