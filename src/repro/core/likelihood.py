"""Training log-likelihood of the collapsed LDA state.

The paper (§5, Evaluation) tracks the training log-likelihood
``log p(W, Z | α, β)`` of the latest sample as the convergence surrogate.
For symmetric β and (possibly asymmetric) α the collapsed joint is

  log p(W,Z) = Σ_k [ lnΓ(Vβ) − lnΓ(C_k + Vβ) + Σ_t (lnΓ(C_k^t + β) − lnΓ(β)) ]
             + Σ_d [ lnΓ(Σα) − lnΓ(N_d + Σα) + Σ_k (lnΓ(C_d^k + α_k) − lnΓ(α_k)) ]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.scipy.special import gammaln

from repro.core.counts import CountState


@jax.jit
def word_log_likelihood(ckt: jax.Array, ck: jax.Array, beta: float) -> jax.Array:
    """The word-side (topic) term; separable over word-topic rows, so the
    model-parallel engine can evaluate it block-locally and psum."""
    v = ckt.shape[0]
    k = ck.shape[0]
    vbeta = beta * v
    term = jnp.sum(gammaln(ckt.astype(jnp.float32) + beta)) - v * k * gammaln(
        jnp.float32(beta))
    return (term + k * gammaln(jnp.float32(vbeta))
            - jnp.sum(gammaln(ck.astype(jnp.float32) + vbeta)))


@jax.jit
def doc_log_likelihood(cdk: jax.Array, alpha: jax.Array) -> jax.Array:
    """The document-side term; separable over document shards."""
    alpha = jnp.asarray(alpha, jnp.float32)
    d = cdk.shape[0]
    nd = cdk.sum(axis=1).astype(jnp.float32)
    asum = alpha.sum()
    term = jnp.sum(gammaln(cdk.astype(jnp.float32) + alpha[None, :]))
    return (term - d * jnp.sum(gammaln(alpha))
            + d * gammaln(asum) - jnp.sum(gammaln(nd + asum)))


def log_likelihood(state: CountState, alpha, beta) -> float:
    """Full collapsed joint log p(W, Z) (host convenience)."""
    lw = word_log_likelihood(state.ckt, state.ck, beta)
    ld = doc_log_likelihood(state.cdk, jnp.asarray(alpha, jnp.float32))
    return float(lw + ld)
